//! `vizier-server` — launcher for the OSS Vizier service (paper Code
//! Block 4 equivalent).
//!
//! ```text
//! vizier-server serve  --host 127.0.0.1 --port 6006 --datastore wal \
//!                      --wal-path ./vizier.wal --workers 8 --policy-workers 100
//! vizier-server pythia --port 6007 --api-addr 127.0.0.1:6006
//! vizier-server serve  --port 6006 --pythia-addr 127.0.0.1:6007
//! vizier-server serve  --port 6006 --legacy-threads   # thread/conn baseline
//! ```
//!
//! `serve` runs the API service (in-process Pythia by default, or remote
//! via `--pythia-addr`); `pythia` runs the standalone Pythia policy
//! service of Figure 2. `--workers` sizes the front-end worker pool (the
//! event-loop + bounded-pool model of `service::frontend`; default = CPU
//! count), `--legacy-threads` restores the thread-per-connection model
//! as a comparison baseline, and `--policy-workers` sizes the policy
//! computation pool (the paper's `max_workers=100`).

use ossvizier::datastore::memory::InMemoryDatastore;
use ossvizier::datastore::wal::WalDatastore;
use ossvizier::datastore::Datastore;
use ossvizier::pythia::runner::default_registry;
use ossvizier::service::remote_pythia::{PythiaServer, RemotePythia};
use ossvizier::service::{build_service, ServerOptions, VizierServer, VizierService};
use ossvizier::util::cli::{usage, Args, OptSpec};
use std::sync::Arc;

fn specs() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "host", takes_value: true, help: "bind host (default 127.0.0.1)" },
        OptSpec { name: "port", takes_value: true, help: "bind port (default 6006)" },
        OptSpec { name: "datastore", takes_value: true, help: "memory | wal (default memory)" },
        OptSpec { name: "shards", takes_value: true, help: "in-memory datastore shard count (default 16)" },
        OptSpec { name: "wal-path", takes_value: true, help: "WAL path: a file, or a directory with --wal-segment-bytes (default ./vizier.wal)" },
        OptSpec { name: "wal-sync", takes_value: false, help: "fsync each WAL commit batch (machine-crash durability)" },
        OptSpec { name: "wal-serial", takes_value: false, help: "disable WAL group commit (serial appends; baseline)" },
        OptSpec { name: "wal-segment-bytes", takes_value: true, help: "segmented WAL: rotate the active segment at this size; compaction runs in the background without stalling commits (0 = single-file baseline, the default)" },
        OptSpec { name: "wal-serial-apply", takes_value: false, help: "one global commit lane instead of per-shard lanes (serialized-apply baseline)" },
        OptSpec { name: "wal-auto-compact-segments", takes_value: true, help: "auto-compact when more than N segment files exist (0 = manual only, the default; needs --wal-segment-bytes)" },
        OptSpec { name: "wal-compact-amplification", takes_value: true, help: "auto-compact when the live log exceeds N x the last compaction base (bytes amplification; 0 = off, the default; needs --wal-segment-bytes)" },
        OptSpec { name: "workers", takes_value: true, help: "front-end worker-pool threads (default: CPU count)" },
        OptSpec { name: "idle-timeout-secs", takes_value: true, help: "evict connections idle longer than this (0 = never, the default)" },
        OptSpec { name: "max-connections", takes_value: true, help: "refuse connections beyond this many (0 = unlimited, the default)" },
        OptSpec { name: "legacy-threads", takes_value: false, help: "thread-per-connection front-end (benchmark baseline)" },
        OptSpec { name: "poller", takes_value: true, help: "event-loop readiness backend: epoll (default, incremental registration) | poll (rebuilt-per-wakeup baseline)" },
        OptSpec { name: "datastore-cow", takes_value: true, help: "datastore read path: on (default, copy-on-write snapshots; lock-free readers + zero-lock compaction) | off (lock-per-read baseline); default honors OSSVIZIER_DATASTORE_COW" },
        OptSpec { name: "policy-workers", takes_value: true, help: "policy worker threads (default 100, Code Block 4)" },
        OptSpec { name: "pythia-addr", takes_value: true, help: "run policies on a remote Pythia server at this addr" },
        OptSpec { name: "api-addr", takes_value: true, help: "pythia mode: the API server for datastore reads" },
        OptSpec { name: "metrics-secs", takes_value: true, help: "print service metrics every N seconds (0 = off)" },
        OptSpec { name: "trace-sample-rate", takes_value: true, help: "fraction of requests to trace, 0.0-1.0 (default 0; overrides OSSVIZIER_TRACE)" },
        OptSpec { name: "trace-slow-ms", takes_value: true, help: "print the span tree of any request slower than N ms to stderr (implies tracing)" },
        OptSpec { name: "help", takes_value: false, help: "show usage" },
    ]
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (mode, rest) = match argv.first().map(|s| s.as_str()) {
        Some("serve") => ("serve", &argv[1..]),
        Some("pythia") => ("pythia", &argv[1..]),
        _ => ("serve", &argv[..]),
    };
    let args = match Args::parse(rest, &specs()) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{}", usage("vizier-server [serve|pythia]", &specs()));
            std::process::exit(2);
        }
    };
    if args.has_flag("help") {
        println!("{}", usage("vizier-server [serve|pythia]", &specs()));
        return;
    }
    let host = args.get_or("host", "127.0.0.1").to_string();
    let port = args.get_u64("port", if mode == "pythia" { 6007 } else { 6006 }).unwrap_or(6006);
    let addr = format!("{host}:{port}");

    // Latch the tracing config before any server thread can record a
    // span (both modes). Absent flags fall back to OSSVIZIER_TRACE.
    let trace_rate = args.get("trace-sample-rate").map(|v| {
        v.parse::<f64>()
            .unwrap_or_else(|_| fatal(&format!("--trace-sample-rate must be a number, got {v:?}")))
    });
    let trace_slow = args.get("trace-slow-ms").map(|v| {
        v.parse::<u64>()
            .unwrap_or_else(|_| fatal(&format!("--trace-slow-ms must be an integer, got {v:?}")))
    });
    ossvizier::util::trace::init(trace_rate, trace_slow);

    match mode {
        "pythia" => {
            let api_addr = args.get_or("api-addr", "127.0.0.1:6006").to_string();
            let workers = args.get_u64("workers", 0).unwrap_or(0) as usize;
            let server = PythiaServer::start_with(default_registry(), &api_addr, &addr, workers)
                .unwrap_or_else(|e| fatal(&format!("bind {addr}: {e}")));
            println!("pythia service listening on {} (api server: {api_addr})", server.local_addr());
            park();
        }
        _ => {
            let mut wal_metrics = None;
            let datastore_cow: Option<bool> = match args.get("datastore-cow") {
                Some("on") | Some("1") | Some("true") => Some(true),
                Some("off") | Some("0") | Some("false") => Some(false),
                Some(other) => fatal(&format!("unknown --datastore-cow {other:?} (on|off)")),
                None => None,
            };
            let ds_metrics;
            let ds: Arc<dyn Datastore> = match args.get_or("datastore", "memory") {
                "wal" => {
                    let path = args.get_or("wal-path", "./vizier.wal").to_string();
                    let segment_bytes = args.get_u64("wal-segment-bytes", 0).unwrap_or(0);
                    let opts = ossvizier::datastore::wal::WalOptions {
                        sync: args.has_flag("wal-sync"),
                        group_commit: !args.has_flag("wal-serial"),
                        serial_apply: args.has_flag("wal-serial-apply"),
                        segment_bytes: (segment_bytes > 0).then_some(segment_bytes),
                        auto_compact_segments: args
                            .get_u64("wal-auto-compact-segments", 0)
                            .unwrap_or(0),
                        compact_amplification: args
                            .get_u64("wal-compact-amplification", 0)
                            .unwrap_or(0),
                        datastore_cow,
                    };
                    let ds = WalDatastore::open_with_options(&path, opts)
                        .unwrap_or_else(|e| fatal(&format!("open wal {path}: {e}")));
                    println!(
                        "durable datastore at {path} ({} bytes in {} segment(s), \
                         group_commit={}, serial_apply={}, sync={})",
                        ds.log_size(),
                        ds.segment_count(),
                        opts.group_commit,
                        opts.serial_apply,
                        opts.sync
                    );
                    wal_metrics = Some(ds.metrics());
                    ds_metrics = ds.datastore_metrics();
                    Arc::new(ds)
                }
                "memory" => {
                    let shards = args.get_u64("shards", 16).unwrap_or(16) as usize;
                    let cow = datastore_cow.unwrap_or_else(
                        ossvizier::datastore::memory::cow_default_from_env,
                    );
                    let mem = InMemoryDatastore::with_shards_cow(shards, cow);
                    ds_metrics = mem.metrics();
                    Arc::new(mem)
                }
                other => fatal(&format!("unknown datastore {other:?} (memory|wal)")),
            };
            let policy_workers = args.get_u64("policy-workers", 100).unwrap_or(100) as usize;
            let service: Arc<VizierService> = match args.get("pythia-addr") {
                Some(pythia_addr) => {
                    println!("policies run on remote pythia at {pythia_addr}");
                    VizierService::new(ds, Arc::new(RemotePythia::new(pythia_addr)), policy_workers)
                }
                None => build_service(ds, |_| {}, policy_workers),
            };
            // Durable-store gauges show up in GetServiceMetrics / the
            // periodic report alongside the RPC histograms.
            if let Some(m) = wal_metrics {
                service.metrics.set_wal(m);
            }
            service.metrics.set_datastore(ds_metrics);
            // Server-side fault tolerance: resume interrupted operations.
            match service.resume_pending_operations() {
                Ok(0) => {}
                Ok(n) => println!("resumed {n} interrupted operation(s) from the datastore"),
                Err(e) => eprintln!("warning: could not resume operations: {e}"),
            }
            let metrics = Arc::clone(&service.metrics);
            let fe_workers = args.get_u64("workers", 0).unwrap_or(0) as usize;
            let legacy = args.has_flag("legacy-threads");
            let idle_secs = args.get_u64("idle-timeout-secs", 0).unwrap_or(0);
            let idle_timeout =
                (idle_secs > 0).then(|| std::time::Duration::from_secs(idle_secs));
            let max_connections = args.get_u64("max-connections", 0).unwrap_or(0) as usize;
            let poller = match args.get("poller") {
                Some(s) => ossvizier::util::netpoll::PollerKind::parse(s)
                    .unwrap_or_else(|| fatal(&format!("unknown poller {s:?} (poll|epoll)"))),
                None => ossvizier::util::netpoll::PollerKind::from_env(),
            };
            let opts = ServerOptions {
                workers: fe_workers,
                legacy_threads: legacy,
                idle_timeout,
                max_connections,
                poller,
                ..Default::default()
            };
            let server = VizierServer::start_with(service, &addr, opts)
                .unwrap_or_else(|e| fatal(&format!("bind {addr}: {e}")));
            if legacy {
                println!(
                    "vizier service listening on {} (legacy thread-per-connection front-end, \
                     {policy_workers} policy workers)",
                    server.local_addr()
                );
            } else {
                let shown = if fe_workers == 0 {
                    ossvizier::service::frontend::default_workers()
                } else {
                    fe_workers
                };
                println!(
                    "vizier service listening on {} ({shown} front-end workers, \
                     {} poller, {policy_workers} policy workers)",
                    server.local_addr(),
                    poller.name()
                );
            }

            let metrics_secs = args.get_u64("metrics-secs", 0).unwrap_or(0);
            if metrics_secs > 0 {
                loop {
                    std::thread::sleep(std::time::Duration::from_secs(metrics_secs));
                    println!("{}", metrics.report());
                }
            }
            park();
        }
    }
}

fn park() -> ! {
    loop {
        std::thread::park();
    }
}

fn fatal(msg: &str) -> ! {
    eprintln!("fatal: {msg}");
    std::process::exit(1);
}
