//! Random search: uniform sampling in scaled space (the default algorithm,
//! Code Block 1).

use crate::pythia::policy::{Policy, PolicyError, SuggestDecision, SuggestRequest};
use crate::pythia::supporter::PolicySupporter;
use crate::pyvizier::TrialSuggestion;

/// Uniform random suggestions, conditional-search aware.
pub struct RandomSearchPolicy;

impl Policy for RandomSearchPolicy {
    fn suggest(
        &mut self,
        req: &SuggestRequest,
        supporter: &dyn PolicySupporter,
    ) -> Result<SuggestDecision, PolicyError> {
        // Salt with the number of existing trials so consecutive operations
        // draw fresh samples but a crash-replayed operation is identical.
        let salt = supporter.trial_count(&req.study_name)? as u64;
        let mut rng = super::op_rng(&req.study_config, &req.study_name, salt);
        let suggestions = (0..req.total_count())
            .map(|_| TrialSuggestion::new(req.study_config.search_space.sample(&mut rng)))
            .collect();
        Ok(SuggestDecision::from_flat(req, suggestions))
    }

    fn name(&self) -> &str {
        "random-search"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policies::test_support::{run_suggest, test_study};

    #[test]
    fn suggestions_are_feasible_and_deterministic() {
        let (ds, study, config) = test_study("RANDOM_SEARCH");
        let a = run_suggest(&ds, &study, &config, 8);
        let b = run_suggest(&ds, &study, &config, 8);
        assert_eq!(a.len(), 8);
        for s in &a {
            config.search_space.validate(&s.parameters).unwrap();
        }
        // Same datastore state -> same op output (crash-replay determinism).
        assert_eq!(a, b);
    }

    #[test]
    fn different_trial_counts_give_different_draws() {
        let (ds, study, config) = test_study("RANDOM_SEARCH");
        let a = run_suggest(&ds, &study, &config, 4);
        crate::policies::test_support::add_completed_random(&ds, &study, &config, 3);
        let b = run_suggest(&ds, &study, &config, 4);
        assert_ne!(a, b);
    }
}
