//! Firefly algorithm (Yang, 2010) — §6.3's third named meta-heuristic.
//! Fireflies (evaluated points) attract each other with brightness
//! (fitness); a new suggestion moves a firefly toward a brighter one in
//! unit space with distance-decayed attraction plus a random walk.

use super::population::{
    designer_rng, member_from_trial, population_from_json, population_to_json, Member,
};
use crate::pythia::designer::{Designer, SerializableDesigner};
use crate::pythia::policy::PolicyError;
use crate::pyvizier::search_space::{ParameterConfig, ParameterKind};
use crate::pyvizier::{scaling, Metadata, ParameterValue, StudyConfig, Trial, TrialSuggestion};
use crate::util::rng::Pcg32;

/// Swarm capacity.
pub const SWARM: usize = 20;
/// Base attractiveness.
const BETA0: f64 = 0.8;
/// Light-absorption coefficient.
const GAMMA: f64 = 2.0;
/// Random-walk scale.
const ALPHA: f64 = 0.08;

pub struct FireflyDesigner {
    config: StudyConfig,
    swarm: Vec<Member>,
    absorbed: u64,
}

/// Project a parameter to unit space (ordinal embedding for
/// discrete/categorical values).
pub(crate) fn to_unit_value(cfg: &ParameterConfig, v: &ParameterValue) -> f64 {
    match &cfg.kind {
        ParameterKind::Double { min, max } => {
            scaling::to_unit(cfg.scale, *min, *max, v.as_f64().unwrap_or(*min))
        }
        ParameterKind::Integer { min, max } => {
            let span = (max - min).max(1) as f64;
            (v.as_i64().unwrap_or(*min) - min) as f64 / span
        }
        ParameterKind::Discrete { values } => {
            let x = v.as_f64().unwrap_or(values[0]);
            let idx = values.iter().position(|&d| d == x).unwrap_or(0);
            idx as f64 / (values.len() - 1).max(1) as f64
        }
        ParameterKind::Categorical { values } => {
            let idx = v
                .as_str()
                .and_then(|s| values.iter().position(|c| c == s))
                .unwrap_or(0);
            idx as f64 / (values.len() - 1).max(1) as f64
        }
    }
}

/// Inverse of [`to_unit_value`].
pub(crate) fn from_unit_value(cfg: &ParameterConfig, u: f64) -> ParameterValue {
    let u = u.clamp(0.0, 1.0);
    match &cfg.kind {
        ParameterKind::Double { min, max } => {
            ParameterValue::F64(scaling::from_unit(cfg.scale, *min, *max, u))
        }
        ParameterKind::Integer { min, max } => {
            let span = (max - min) as f64;
            ParameterValue::I64(min + (u * span).round() as i64)
        }
        ParameterKind::Discrete { values } => {
            let idx = (u * (values.len() - 1) as f64).round() as usize;
            ParameterValue::F64(values[idx])
        }
        ParameterKind::Categorical { values } => {
            let idx = (u * (values.len() - 1) as f64).round() as usize;
            ParameterValue::Str(values[idx].clone())
        }
    }
}

impl FireflyDesigner {
    /// Move firefly `i` toward a brighter firefly `j` (if any) in unit space.
    fn fly(&self, i: usize, rng: &mut Pcg32) -> TrialSuggestion {
        let space = &self.config.search_space;
        let me = &self.swarm[i];
        // The brightest firefly other than me.
        let target = self
            .swarm
            .iter()
            .filter(|m| m.fitness() > me.fitness())
            .max_by(|a, b| a.fitness().partial_cmp(&b.fitness()).unwrap());
        let params = space.assemble(|cfg| {
            let x = to_unit_value(cfg, me.params.get(&cfg.name).unwrap_or(&ParameterValue::F64(0.0)));
            let moved = match target {
                Some(t) => {
                    let y = to_unit_value(
                        cfg,
                        t.params.get(&cfg.name).unwrap_or(&ParameterValue::F64(0.0)),
                    );
                    let r2 = (y - x) * (y - x);
                    let beta = BETA0 * (-GAMMA * r2).exp();
                    x + beta * (y - x) + ALPHA * (rng.f64() - 0.5)
                }
                // Brightest firefly wanders randomly.
                None => x + 2.0 * ALPHA * (rng.f64() - 0.5),
            };
            from_unit_value(cfg, moved)
        });
        TrialSuggestion::new(params)
    }
}

impl Designer for FireflyDesigner {
    fn update(&mut self, completed: &[Trial]) {
        for t in completed {
            self.absorbed += 1;
            if let Some(m) = member_from_trial(t, &self.config.metrics) {
                self.swarm.push(m);
                self.swarm
                    .sort_by(|a, b| b.fitness().partial_cmp(&a.fitness()).unwrap());
                self.swarm.truncate(SWARM);
            }
        }
    }

    fn suggest(&mut self, count: usize) -> Result<Vec<TrialSuggestion>, PolicyError> {
        let mut rng = designer_rng(&self.config, self.absorbed ^ 0xF1);
        let space = self.config.search_space.clone();
        Ok((0..count)
            .map(|k| {
                if self.swarm.is_empty() {
                    TrialSuggestion::new(space.sample(&mut rng))
                } else {
                    self.fly(k % self.swarm.len(), &mut rng)
                }
            })
            .collect())
    }
}

impl SerializableDesigner for FireflyDesigner {
    fn designer_name() -> &'static str {
        "firefly"
    }

    fn from_config(config: &StudyConfig) -> Result<Self, PolicyError> {
        if config.metrics.len() != 1 {
            return Err(PolicyError::Unsupported("firefly is single-objective".into()));
        }
        Ok(Self {
            config: config.clone(),
            swarm: Vec::new(),
            absorbed: 0,
        })
    }

    fn dump(&self) -> Metadata {
        let mut md = Metadata::new();
        md.put_str("", "swarm", &population_to_json(&self.swarm));
        md.put_str("", "absorbed", &self.absorbed.to_string());
        md
    }

    fn recover(config: &StudyConfig, md: &Metadata) -> Result<Self, PolicyError> {
        let missing = || PolicyError::CorruptState("missing swarm".into());
        Ok(Self {
            config: config.clone(),
            swarm: population_from_json(md.get_str("", "swarm").ok_or_else(missing)?)?,
            absorbed: md
                .get_str("", "absorbed")
                .and_then(|s| s.parse().ok())
                .ok_or_else(missing)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policies::test_support::*;
    use crate::pyvizier::{Measurement, ParameterDict, TrialState};

    fn trial(id: u64, lr: f64, score: f64) -> Trial {
        let mut p = ParameterDict::new();
        p.set("lr", lr).set("layers", 4i64).set("opt", "sgd");
        let mut t = Trial::new(id, p);
        t.state = TrialState::Completed;
        t.final_measurement = Some(Measurement::new(1).with_metric("score", score));
        t
    }

    #[test]
    fn unit_embedding_roundtrip() {
        let cfgs = vec![
            ParameterConfig::double("x", -2.0, 3.0),
            ParameterConfig::integer("i", 1, 9),
            ParameterConfig::discrete("d", vec![0.5, 1.0, 8.0]),
            ParameterConfig::categorical("c", vec!["a", "b", "c"]),
        ];
        let mut rng = Pcg32::seeded(5);
        for cfg in &cfgs {
            for _ in 0..50 {
                let v = cfg.sample_value(&mut rng);
                let u = to_unit_value(cfg, &v);
                assert!((0.0..=1.0).contains(&u));
                let back = from_unit_value(cfg, u);
                // Roundtrip exact for non-continuous kinds.
                if !matches!(cfg.kind, ParameterKind::Double { .. }) {
                    assert!(back.matches(&v), "{cfg:?}: {v:?} -> {u} -> {back:?}");
                }
            }
        }
    }

    #[test]
    fn dim_fireflies_move_toward_bright_ones() {
        let (_, _, config) = test_study("FIREFLY");
        let mut d = FireflyDesigner::from_config(&config).unwrap();
        let mut trials = vec![trial(1, 1e-2, 100.0)]; // bright, lr = 1e-2
        trials.extend((2..=8).map(|i| trial(i, 1e-4, 1.0))); // dim, lr = 1e-4
        d.update(&trials);
        let suggestions = d.suggest(24).unwrap();
        // Dim flies (lr=1e-4, unit 0) move toward the bright one (unit ~1);
        // average log-lr must exceed the dim baseline.
        let mean_loglr: f64 = suggestions
            .iter()
            .map(|s| {
                config.search_space.validate(&s.parameters).unwrap();
                s.parameters.get_f64("lr").unwrap().log10()
            })
            .sum::<f64>()
            / suggestions.len() as f64;
        assert!(mean_loglr > -3.8, "mean log lr {mean_loglr} should move up from -4");
    }

    #[test]
    fn state_roundtrip_and_policy_path() {
        let (ds, study, config) = test_study("FIREFLY");
        add_completed_random(&ds, &study, &config, 5);
        let s = run_suggest(&ds, &study, &config, 4);
        assert_eq!(s.len(), 4);
        let mut d = FireflyDesigner::from_config(&config).unwrap();
        d.update(&(1..=5).map(|i| trial(i, 1e-3, i as f64)).collect::<Vec<_>>());
        let d2 = FireflyDesigner::recover(&config, &d.dump()).unwrap();
        assert_eq!(d2.swarm, d.swarm);
    }
}
