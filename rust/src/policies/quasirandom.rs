//! Quasi-random search using the Halton low-discrepancy sequence: better
//! space coverage than i.i.d. random for moderate dimensions.

use crate::pythia::policy::{Policy, PolicyError, SuggestDecision, SuggestRequest};
use crate::pythia::supporter::PolicySupporter;
use crate::pyvizier::search_space::{ParameterConfig, ParameterKind};
use crate::pyvizier::{scaling, ParameterValue, TrialSuggestion};

const PRIMES: [u64; 24] = [
    2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37, 41, 43, 47, 53, 59, 61, 67, 71, 73, 79, 83, 89,
];

/// The `i`-th element of the base-`b` van der Corput sequence.
pub fn van_der_corput(mut i: u64, b: u64) -> f64 {
    let mut f = 1.0;
    let mut r = 0.0;
    while i > 0 {
        f /= b as f64;
        r += f * (i % b) as f64;
        i /= b;
    }
    r
}

/// The `i`-th Halton point in `dims` dimensions (dimension d uses the d-th
/// prime base; for d beyond the table we fall back to a scrambled base-2).
pub fn halton(i: u64, dims: usize) -> Vec<f64> {
    (0..dims)
        .map(|d| {
            if d < PRIMES.len() {
                van_der_corput(i, PRIMES[d])
            } else {
                // Cranley-Patterson rotation of base-2 for high dims.
                let shift = (d as f64 * 0.6180339887498949).fract();
                (van_der_corput(i, 2) + shift).fract()
            }
        })
        .collect()
}

fn value_from_unit(cfg: &ParameterConfig, u: f64) -> ParameterValue {
    match &cfg.kind {
        ParameterKind::Double { min, max } => {
            ParameterValue::F64(scaling::from_unit(cfg.scale, *min, *max, u))
        }
        ParameterKind::Integer { min, max } => {
            let k = (max - min + 1) as f64;
            ParameterValue::I64(min + ((u * k).floor() as i64).min(max - min))
        }
        ParameterKind::Discrete { values } => {
            let idx = ((u * values.len() as f64).floor() as usize).min(values.len() - 1);
            ParameterValue::F64(values[idx])
        }
        ParameterKind::Categorical { values } => {
            let idx = ((u * values.len() as f64).floor() as usize).min(values.len() - 1);
            ParameterValue::Str(values[idx].clone())
        }
    }
}

/// Build the assignment for Halton index `i` (skipping the first `SKIP`
/// points, which are poorly distributed).
const SKIP: u64 = 20;

pub fn halton_point(
    space: &crate::pyvizier::SearchSpace,
    i: u64,
) -> crate::pyvizier::ParameterDict {
    let configs = space.all_configs();
    let point = halton(i + SKIP, configs.len());
    let units: std::collections::HashMap<String, f64> = configs
        .iter()
        .zip(&point)
        .map(|(c, &u)| (c.name.clone(), u))
        .collect();
    space.assemble(|cfg| value_from_unit(cfg, units[&cfg.name]))
}

/// Quasi-random policy: the k-th suggestion is the k-th Halton point.
pub struct QuasiRandomPolicy;

impl Policy for QuasiRandomPolicy {
    fn suggest(
        &mut self,
        req: &SuggestRequest,
        supporter: &dyn PolicySupporter,
    ) -> Result<SuggestDecision, PolicyError> {
        let start = supporter.trial_count(&req.study_name)? as u64;
        let suggestions = (0..req.total_count() as u64)
            .map(|i| TrialSuggestion::new(halton_point(&req.study_config.search_space, start + i)))
            .collect();
        Ok(SuggestDecision::from_flat(req, suggestions))
    }

    fn name(&self) -> &str {
        "quasirandom-search"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policies::test_support::{run_suggest, test_study};

    #[test]
    fn van_der_corput_base2_known_values() {
        assert_eq!(van_der_corput(1, 2), 0.5);
        assert_eq!(van_der_corput(2, 2), 0.25);
        assert_eq!(van_der_corput(3, 2), 0.75);
        assert_eq!(van_der_corput(4, 2), 0.125);
    }

    #[test]
    fn halton_covers_unit_square_with_low_discrepancy() {
        // Count points in each quadrant of [0,1]^2; Halton should be near
        // perfectly balanced while random typically is not.
        let n = 256;
        let mut counts = [0u32; 4];
        for i in 0..n {
            let p = halton(i + SKIP, 2);
            let q = (p[0] >= 0.5) as usize * 2 + (p[1] >= 0.5) as usize;
            counts[q] += 1;
        }
        for &c in &counts {
            assert!((c as i64 - 64).unsigned_abs() <= 4, "quadrant counts {counts:?}");
        }
    }

    #[test]
    fn points_are_feasible_and_distinct() {
        let (ds, study, config) = test_study("QUASI_RANDOM_SEARCH");
        let suggestions = run_suggest(&ds, &study, &config, 16);
        for s in &suggestions {
            config.search_space.validate(&s.parameters).unwrap();
        }
        let distinct: std::collections::HashSet<String> = suggestions
            .iter()
            .map(|s| format!("{:?}", s.parameters))
            .collect();
        assert_eq!(distinct.len(), 16);
    }

    #[test]
    fn integer_mapping_covers_all_values() {
        let cfg = ParameterConfig::integer("i", 1, 5);
        let mut seen = std::collections::HashSet::new();
        for k in 0..100 {
            let v = value_from_unit(&cfg, k as f64 / 100.0);
            seen.insert(v.as_i64().unwrap());
        }
        assert_eq!(seen.len(), 5);
        // u = 1.0 must not overflow past max.
        assert_eq!(value_from_unit(&cfg, 1.0).as_i64(), Some(5));
    }
}
