//! Hill climbing / stochastic local search: mutate the best trial seen so
//! far by a small step in scaled space (one of the §6.3 "local search
//! methods" whose per-operation cost is O(1) in the trial count — it reads
//! only the best trial, not the whole history).

use crate::datastore::query::TrialFilter;
use crate::pythia::policy::{Policy, PolicyError, SuggestDecision, SuggestRequest};
use crate::pythia::supporter::PolicySupporter;
use crate::pyvizier::search_space::{ParameterConfig, ParameterKind};
use crate::pyvizier::{scaling, ParameterDict, ParameterValue, TrialSuggestion};
use crate::util::rng::Pcg32;

/// Std-dev of the Gaussian mutation step, in unit space.
pub const STEP: f64 = 0.08;

/// Mutate one assignment: every numeric parameter takes a small Gaussian
/// step in its scaled space; categorical parameters re-roll with prob 0.2.
pub fn mutate(
    space: &crate::pyvizier::SearchSpace,
    base: &ParameterDict,
    rng: &mut Pcg32,
    step: f64,
) -> ParameterDict {
    space.assemble(|cfg| match base.get(&cfg.name) {
        Some(v) => mutate_value(cfg, v, rng, step),
        None => cfg.sample_value(rng), // param inactive in base: sample
    })
}

/// Mutate a single parameter value within its config.
pub fn mutate_value(
    cfg: &ParameterConfig,
    v: &ParameterValue,
    rng: &mut Pcg32,
    step: f64,
) -> ParameterValue {
    match &cfg.kind {
        ParameterKind::Double { min, max } => {
            let x = v.as_f64().unwrap_or((min + max) / 2.0);
            let u = scaling::to_unit(cfg.scale, *min, *max, x) + rng.normal() * step;
            ParameterValue::F64(scaling::from_unit(cfg.scale, *min, *max, u.clamp(0.0, 1.0)))
        }
        ParameterKind::Integer { min, max } => {
            let x = v.as_i64().unwrap_or(*min);
            let span = (max - min).max(1) as f64;
            let delta = (rng.normal() * step * span).round() as i64;
            // Ensure movement is possible even for tiny spans.
            let delta = if delta == 0 && rng.bool_with(0.5) {
                if rng.bool_with(0.5) {
                    1
                } else {
                    -1
                }
            } else {
                delta
            };
            ParameterValue::I64((x + delta).clamp(*min, *max))
        }
        ParameterKind::Discrete { values } => {
            let x = v.as_f64().unwrap_or(values[0]);
            let idx = values.iter().position(|&d| d == x).unwrap_or(0) as i64;
            let delta = if rng.bool_with(0.5) { 1 } else { -1 };
            let nidx = (idx + delta).clamp(0, values.len() as i64 - 1) as usize;
            ParameterValue::F64(values[nidx])
        }
        ParameterKind::Categorical { values } => {
            if rng.bool_with(0.2) {
                ParameterValue::Str(rng.choose(values).clone())
            } else {
                v.clone()
            }
        }
    }
}

/// The hill-climbing policy.
pub struct HillClimbPolicy;

impl Policy for HillClimbPolicy {
    fn suggest(
        &mut self,
        req: &SuggestRequest,
        supporter: &dyn PolicySupporter,
    ) -> Result<SuggestDecision, PolicyError> {
        let config = &req.study_config;
        let count = supporter.trial_count(&req.study_name)? as u64;
        let mut rng = super::op_rng(config, &req.study_name, count);

        // Read only recent completed trials, newest-first capped — the
        // incumbent is overwhelmingly likely to be recent in hill climbing.
        let completed =
            supporter.trials(&req.study_name, &TrialFilter::completed().with_limit(64))?;
        let best = config.best_trial(completed.iter());

        let suggestions = (0..req.total_count())
            .map(|_| match best {
                Some(t) => TrialSuggestion::new(mutate(
                    &config.search_space,
                    &t.parameters,
                    &mut rng,
                    STEP,
                )),
                None => TrialSuggestion::new(config.search_space.sample(&mut rng)),
            })
            .collect();
        Ok(SuggestDecision::from_flat(req, suggestions))
    }

    fn name(&self) -> &str {
        "hill-climb"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policies::test_support::*;

    #[test]
    fn seeds_randomly_then_exploits_best() {
        let (ds, study, config) = test_study("HILL_CLIMB");
        // No trials yet: random seeding, feasible.
        let s = run_suggest(&ds, &study, &config, 4);
        for sg in &s {
            config.search_space.validate(&sg.parameters).unwrap();
        }

        // Plant a known-best trial at lr=0.01 (optimum of the test score).
        let mut best = crate::pyvizier::ParameterDict::new();
        best.set("lr", 0.01).set("layers", 3i64).set("opt", "adam");
        add_completed_with(&ds, &study, &config, best.clone());
        add_completed_random(&ds, &study, &config, 5);

        let s = run_suggest(&ds, &study, &config, 16);
        // Mutations should cluster near the incumbent in log-space.
        let near = s
            .iter()
            .filter(|sg| {
                let lr = sg.parameters.get_f64("lr").unwrap();
                (lr.log10() - (-2.0)).abs() < 0.8
            })
            .count();
        assert!(near >= 12, "{near}/16 suggestions near incumbent");
        for sg in &s {
            config.search_space.validate(&sg.parameters).unwrap();
        }
    }

    #[test]
    fn mutate_respects_bounds() {
        let mut space = crate::pyvizier::SearchSpace::new();
        space.add_float("x", 0.0, 1.0, crate::wire::messages::ScaleType::Linear);
        space.add_int("i", 0, 3);
        space.add_discrete("d", vec![1.0, 2.0, 4.0]);
        space.add_categorical("c", vec!["a", "b"]);
        let mut rng = crate::util::rng::Pcg32::seeded(1);
        let base = space.sample(&mut rng);
        for _ in 0..500 {
            let m = mutate(&space, &base, &mut rng, 0.5);
            space.validate(&m).unwrap();
        }
    }
}
