//! NSGA-II (Deb et al., 2002) as a [`SerializableDesigner`] — the paper's
//! named multi-objective algorithm (§6.3). Selection uses non-dominated
//! rank + crowding distance; variation is uniform crossover + mutation.

use super::hill_climb::mutate_value;
use super::population::{
    designer_rng, member_from_trial, population_from_json, population_to_json, Member,
};
use crate::pythia::designer::{Designer, SerializableDesigner};
use crate::pythia::policy::PolicyError;
use crate::pyvizier::pareto::{crowding_distance, non_dominated_ranks};
use crate::pyvizier::{Metadata, StudyConfig, Trial, TrialSuggestion};
use crate::util::rng::Pcg32;

/// Population capacity.
pub const POPULATION: usize = 40;
/// Per-parameter mutation probability.
const MUTATION_P: f64 = 0.25;
/// Mutation step in unit space.
const STEP: f64 = 0.1;

pub struct Nsga2Designer {
    config: StudyConfig,
    population: Vec<Member>,
    absorbed: u64,
}

impl Nsga2Designer {
    /// Environmental selection: keep the best POPULATION members by
    /// (rank asc, crowding desc).
    fn select(&mut self) {
        if self.population.len() <= POPULATION {
            return;
        }
        let points: Vec<Vec<f64>> = self.population.iter().map(|m| m.values.clone()).collect();
        let ranks = non_dominated_ranks(&points);
        // Crowding computed per front.
        let mut crowd = vec![0.0f64; points.len()];
        let max_rank = ranks.iter().copied().max().unwrap_or(0);
        for r in 0..=max_rank {
            let idx: Vec<usize> = (0..points.len()).filter(|&i| ranks[i] == r).collect();
            let front: Vec<Vec<f64>> = idx.iter().map(|&i| points[i].clone()).collect();
            for (pos, &i) in idx.iter().enumerate() {
                crowd[i] = crowding_distance(&front)[pos];
            }
        }
        let mut order: Vec<usize> = (0..self.population.len()).collect();
        order.sort_by(|&a, &b| {
            ranks[a]
                .cmp(&ranks[b])
                .then(crowd[b].partial_cmp(&crowd[a]).unwrap_or(std::cmp::Ordering::Equal))
        });
        order.truncate(POPULATION);
        let mut keep = vec![false; self.population.len()];
        for &i in &order {
            keep[i] = true;
        }
        let mut i = 0;
        self.population.retain(|_| {
            let k = keep[i];
            i += 1;
            k
        });
    }

    /// Binary tournament by (rank, crowding): returns an index.
    fn tournament(&self, ranks: &[usize], crowd: &[f64], rng: &mut Pcg32) -> usize {
        let a = rng.next_below(self.population.len() as u64) as usize;
        let b = rng.next_below(self.population.len() as u64) as usize;
        if ranks[a] < ranks[b] || (ranks[a] == ranks[b] && crowd[a] > crowd[b]) {
            a
        } else {
            b
        }
    }
}

impl Designer for Nsga2Designer {
    fn update(&mut self, completed: &[Trial]) {
        for t in completed {
            self.absorbed += 1;
            if let Some(m) = member_from_trial(t, &self.config.metrics) {
                self.population.push(m);
            }
        }
        self.select();
    }

    fn suggest(&mut self, count: usize) -> Result<Vec<TrialSuggestion>, PolicyError> {
        let mut rng = designer_rng(&self.config, self.absorbed ^ 0x2152);
        let space = self.config.search_space.clone();
        if self.population.len() < 2 {
            return Ok((0..count)
                .map(|_| TrialSuggestion::new(space.sample(&mut rng)))
                .collect());
        }
        let points: Vec<Vec<f64>> = self.population.iter().map(|m| m.values.clone()).collect();
        let ranks = non_dominated_ranks(&points);
        let crowd = crowding_distance(&points);
        Ok((0..count)
            .map(|_| {
                let p1 = self.tournament(&ranks, &crowd, &mut rng);
                let p2 = self.tournament(&ranks, &crowd, &mut rng);
                let (a, b) = (&self.population[p1], &self.population[p2]);
                // Uniform crossover + mutation, walked over active params.
                let params = space.assemble(|cfg| {
                    let donor = if rng.bool_with(0.5) { a } else { b };
                    let v = donor
                        .params
                        .get(&cfg.name)
                        .map(|v| cfg.clamp_value(v))
                        .unwrap_or_else(|| cfg.sample_value(&mut rng));
                    if rng.bool_with(MUTATION_P) {
                        mutate_value(cfg, &v, &mut rng, STEP)
                    } else {
                        v
                    }
                });
                TrialSuggestion::new(params)
            })
            .collect())
    }
}

impl SerializableDesigner for Nsga2Designer {
    fn designer_name() -> &'static str {
        "nsga2"
    }

    fn from_config(config: &StudyConfig) -> Result<Self, PolicyError> {
        if config.metrics.is_empty() {
            return Err(PolicyError::Unsupported("study has no metrics".into()));
        }
        Ok(Self {
            config: config.clone(),
            population: Vec::new(),
            absorbed: 0,
        })
    }

    fn dump(&self) -> Metadata {
        let mut md = Metadata::new();
        md.put_str("", "population", &population_to_json(&self.population));
        md.put_str("", "absorbed", &self.absorbed.to_string());
        md
    }

    fn recover(config: &StudyConfig, md: &Metadata) -> Result<Self, PolicyError> {
        let missing = || PolicyError::CorruptState("missing population".into());
        Ok(Self {
            config: config.clone(),
            population: population_from_json(
                md.get_str("", "population").ok_or_else(missing)?,
            )?,
            absorbed: md
                .get_str("", "absorbed")
                .and_then(|s| s.parse().ok())
                .ok_or_else(missing)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pyvizier::{
        Measurement, MetricInformation, ParameterDict, SearchSpace, TrialState,
    };
    use crate::wire::messages::ScaleType;

    /// Bi-objective test study: maximize f1 = x, minimize f2 = (x-1)^2 + y
    /// over x,y in [0,1] — a simple trade-off curve.
    fn mo_config() -> StudyConfig {
        let mut c = StudyConfig::new("mo");
        c.search_space.add_float("x", 0.0, 1.0, ScaleType::Linear);
        c.search_space.add_float("y", 0.0, 1.0, ScaleType::Linear);
        c.add_metric(MetricInformation::maximize("f1"));
        c.add_metric(MetricInformation::minimize("f2"));
        c.seed = 9;
        c
    }

    fn mo_trial(id: u64, x: f64, y: f64) -> Trial {
        let mut p = ParameterDict::new();
        p.set("x", x).set("y", y);
        let mut t = Trial::new(id, p);
        t.state = TrialState::Completed;
        t.final_measurement = Some(
            Measurement::new(1)
                .with_metric("f1", x)
                .with_metric("f2", (x - 1.0).powi(2) + y),
        );
        t
    }

    #[test]
    fn selection_keeps_nondominated_members() {
        let config = mo_config();
        let mut d = Nsga2Designer::from_config(&config).unwrap();
        // 60 random members -> selection to POPULATION.
        let mut rng = Pcg32::seeded(3);
        let trials: Vec<Trial> = (1..=60)
            .map(|i| mo_trial(i, rng.f64(), rng.f64()))
            .collect();
        let points: Vec<Vec<f64>> = trials
            .iter()
            .filter_map(|t| member_from_trial(t, &config.metrics))
            .map(|m| m.values)
            .collect();
        let ranks = non_dominated_ranks(&points);
        let front0: std::collections::HashSet<u64> = (0..points.len())
            .filter(|&i| ranks[i] == 0)
            .map(|i| (i + 1) as u64)
            .collect();
        d.update(&trials);
        assert_eq!(d.population.len(), POPULATION);
        let kept: std::collections::HashSet<u64> = d.population.iter().map(|m| m.id).collect();
        // Every rank-0 member survives (60 points rarely have >40 on front 0).
        assert!(front0.len() <= POPULATION);
        for id in &front0 {
            assert!(kept.contains(id), "front-0 member {id} evicted");
        }
    }

    #[test]
    fn offspring_feasible_and_state_roundtrips() {
        let config = mo_config();
        let mut d = Nsga2Designer::from_config(&config).unwrap();
        let mut rng = Pcg32::seeded(4);
        d.update(
            &(1..=20)
                .map(|i| mo_trial(i, rng.f64(), rng.f64()))
                .collect::<Vec<_>>(),
        );
        for s in d.suggest(30).unwrap() {
            config.search_space.validate(&s.parameters).unwrap();
        }
        let d2 = Nsga2Designer::recover(&config, &d.dump()).unwrap();
        assert_eq!(d2.population, d.population);
    }

    #[test]
    fn improves_hypervolume_over_generations() {
        let config = mo_config();
        let mut d = Nsga2Designer::from_config(&config).unwrap();
        let mut rng = Pcg32::seeded(5);
        // Seed with a poor initial population (x near 0, y near 1).
        let mut next_id = 1u64;
        let seed_trials: Vec<Trial> = (0..10)
            .map(|_| {
                let t = mo_trial(next_id, rng.f64() * 0.2, 0.8 + rng.f64() * 0.2);
                next_id += 1;
                t
            })
            .collect();
        d.update(&seed_trials);
        let hv = |d: &Nsga2Designer| {
            let pts: Vec<Vec<f64>> = d.population.iter().map(|m| m.values.clone()).collect();
            // maximization orientation; reference point dominated by all.
            crate::pyvizier::pareto::hypervolume_2d(&pts, &[-0.5, -3.0])
        };
        let hv0 = hv(&d);
        for _ in 0..15 {
            let sugg = d.suggest(8).unwrap();
            let trials: Vec<Trial> = sugg
                .iter()
                .map(|s| {
                    let t = mo_trial(
                        next_id,
                        s.parameters.get_f64("x").unwrap(),
                        s.parameters.get_f64("y").unwrap(),
                    );
                    next_id += 1;
                    t
                })
                .collect();
            d.update(&trials);
        }
        let hv1 = hv(&d);
        assert!(hv1 > hv0 * 1.1, "hypervolume {hv0} -> {hv1}");
    }

    use super::super::population::member_from_trial;
    use crate::util::rng::Pcg32;
}
