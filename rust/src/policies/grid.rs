//! Grid search: deterministic enumeration of a mixed-radix grid over the
//! search space. The k-th suggestion is the k-th grid point, where k is
//! the number of already-created trials — so parallel workers collectively
//! sweep the grid exactly once.

use crate::pythia::policy::{Policy, PolicyError, SuggestDecision, SuggestRequest};
use crate::pythia::supporter::PolicySupporter;
use crate::pyvizier::search_space::{ParameterConfig, ParameterKind};
use crate::pyvizier::{scaling, ParameterValue, TrialSuggestion};

/// Number of grid points for continuous parameters.
pub const DOUBLE_RESOLUTION: u64 = 10;

/// Grid cardinality of one parameter.
fn arity(cfg: &ParameterConfig) -> u64 {
    cfg.cardinality().unwrap_or(DOUBLE_RESOLUTION).max(1)
}

/// The `digit`-th of `arity(cfg)` values for a parameter.
fn value_at(cfg: &ParameterConfig, digit: u64) -> ParameterValue {
    match &cfg.kind {
        ParameterKind::Double { min, max } => {
            let k = arity(cfg);
            let u = if k == 1 { 0.5 } else { digit as f64 / (k - 1) as f64 };
            ParameterValue::F64(scaling::from_unit(cfg.scale, *min, *max, u))
        }
        ParameterKind::Integer { min, .. } => ParameterValue::I64(min + digit as i64),
        ParameterKind::Discrete { values } => ParameterValue::F64(values[digit as usize]),
        ParameterKind::Categorical { values } => ParameterValue::Str(values[digit as usize].clone()),
    }
}

/// Decode grid index `k` into an assignment via mixed-radix digits,
/// walking the conditional tree (inactive children consume no digits in
/// effect but we still advance the radix deterministically by assigning
/// digits to every config in flattened order).
pub fn grid_point(
    space: &crate::pyvizier::SearchSpace,
    k: u64,
) -> crate::pyvizier::ParameterDict {
    // Precompute digits for every config in flattened order.
    let configs = space.all_configs();
    let mut digits = std::collections::HashMap::new();
    let mut rem = k;
    for cfg in &configs {
        let a = arity(cfg);
        digits.insert(cfg.name.clone(), rem % a);
        rem /= a;
    }
    space.assemble(|cfg| value_at(cfg, digits[&cfg.name]))
}

/// Total number of grid points.
pub fn grid_size(space: &crate::pyvizier::SearchSpace) -> u64 {
    space
        .all_configs()
        .iter()
        .fold(1u64, |acc, c| acc.saturating_mul(arity(c)))
}

/// The grid-search policy.
pub struct GridSearchPolicy;

impl Policy for GridSearchPolicy {
    fn suggest(
        &mut self,
        req: &SuggestRequest,
        supporter: &dyn PolicySupporter,
    ) -> Result<SuggestDecision, PolicyError> {
        let start = supporter.trial_count(&req.study_name)? as u64;
        let total = grid_size(&req.study_config.search_space);
        let suggestions = (0..req.total_count() as u64)
            .map(|i| {
                let k = (start + i) % total; // wrap after full sweep
                TrialSuggestion::new(grid_point(&req.study_config.search_space, k))
            })
            .collect();
        Ok(SuggestDecision::from_flat(req, suggestions))
    }

    fn name(&self) -> &str {
        "grid-search"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policies::test_support::{run_suggest, test_study};
    use crate::pyvizier::SearchSpace;

    #[test]
    fn covers_entire_discrete_grid_without_repeats() {
        let mut space = SearchSpace::new();
        space.add_int("a", 0, 2).add_categorical("b", vec!["x", "y"]);
        let total = grid_size(&space);
        assert_eq!(total, 6);
        let mut seen = std::collections::HashSet::new();
        for k in 0..total {
            let p = grid_point(&space, k);
            space.validate(&p).unwrap();
            seen.insert(format!("{}|{}", p.get_i64("a").unwrap(), p.get_str("b").unwrap()));
        }
        assert_eq!(seen.len(), 6, "all grid points distinct");
    }

    #[test]
    fn continuous_params_hit_endpoints() {
        let mut space = SearchSpace::new();
        space.add_float("x", -1.0, 1.0, crate::wire::messages::ScaleType::Linear);
        let first = grid_point(&space, 0);
        let last = grid_point(&space, DOUBLE_RESOLUTION - 1);
        assert_eq!(first.get_f64("x"), Some(-1.0));
        assert_eq!(last.get_f64("x"), Some(1.0));
    }

    #[test]
    fn conditional_space_yields_valid_points() {
        let mut space = SearchSpace::new();
        space.add_categorical("model", vec!["linear", "dnn"]);
        space
            .add_conditional(
                "model",
                vec!["dnn".into()],
                crate::pyvizier::search_space::ParameterConfig::integer("layers", 1, 3),
            )
            .unwrap();
        for k in 0..grid_size(&space) {
            let p = grid_point(&space, k);
            space.validate(&p).unwrap();
        }
    }

    #[test]
    fn policy_advances_with_trial_count() {
        let (ds, study, config) = test_study("GRID_SEARCH");
        let first = run_suggest(&ds, &study, &config, 3);
        assert_eq!(first.len(), 3);
        for s in &first {
            config.search_space.validate(&s.parameters).unwrap();
        }
        // Suggestions within a batch are distinct grid points.
        assert_ne!(first[0].parameters, first[1].parameters);
        assert_ne!(first[1].parameters, first[2].parameters);
    }
}
