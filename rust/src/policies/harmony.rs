//! Harmony Search (Lee & Geem, 2005) — one of the §6.3 meta-heuristics:
//! keeps a bounded "harmony memory" of good solutions; each new suggestion
//! draws every parameter either from memory (with pitch adjustment) or
//! uniformly at random.

use super::hill_climb::mutate_value;
use super::population::{
    designer_rng, member_from_trial, population_from_json, population_to_json, Member,
};
use crate::pythia::designer::{Designer, SerializableDesigner};
use crate::pythia::policy::PolicyError;
use crate::pyvizier::{Metadata, StudyConfig, Trial, TrialSuggestion};

/// Harmony memory size.
pub const MEMORY: usize = 20;
/// Harmony-memory considering rate.
pub const HMCR: f64 = 0.9;
/// Pitch-adjusting rate.
pub const PAR: f64 = 0.3;
/// Pitch-adjust bandwidth in unit space.
const BANDWIDTH: f64 = 0.05;

pub struct HarmonySearch {
    config: StudyConfig,
    /// Memory kept sorted best-first; worst evicted.
    memory: Vec<Member>,
    absorbed: u64,
}

impl HarmonySearch {
    fn insert(&mut self, m: Member) {
        self.memory.push(m);
        self.memory
            .sort_by(|a, b| b.fitness().partial_cmp(&a.fitness()).unwrap());
        self.memory.truncate(MEMORY);
    }
}

impl Designer for HarmonySearch {
    fn update(&mut self, completed: &[Trial]) {
        for t in completed {
            self.absorbed += 1;
            if let Some(m) = member_from_trial(t, &self.config.metrics) {
                self.insert(m);
            }
        }
    }

    fn suggest(&mut self, count: usize) -> Result<Vec<TrialSuggestion>, PolicyError> {
        let mut rng = designer_rng(&self.config, self.absorbed ^ 0xA4);
        let space = self.config.search_space.clone();
        Ok((0..count)
            .map(|_| {
                if self.memory.is_empty() {
                    return TrialSuggestion::new(space.sample(&mut rng));
                }
                let params = space.assemble(|cfg| {
                    if rng.bool_with(HMCR) {
                        // Draw this parameter from a random memory member.
                        let donor = &self.memory[rng.next_below(self.memory.len() as u64) as usize];
                        match donor.params.get(&cfg.name) {
                            Some(v) if rng.bool_with(PAR) => {
                                mutate_value(cfg, v, &mut rng, BANDWIDTH)
                            }
                            Some(v) => cfg.clamp_value(v),
                            None => cfg.sample_value(&mut rng),
                        }
                    } else {
                        cfg.sample_value(&mut rng)
                    }
                });
                TrialSuggestion::new(params)
            })
            .collect())
    }
}

impl SerializableDesigner for HarmonySearch {
    fn designer_name() -> &'static str {
        "harmony_search"
    }

    fn from_config(config: &StudyConfig) -> Result<Self, PolicyError> {
        if config.metrics.len() != 1 {
            return Err(PolicyError::Unsupported("harmony search is single-objective".into()));
        }
        Ok(Self {
            config: config.clone(),
            memory: Vec::new(),
            absorbed: 0,
        })
    }

    fn dump(&self) -> Metadata {
        let mut md = Metadata::new();
        md.put_str("", "memory", &population_to_json(&self.memory));
        md.put_str("", "absorbed", &self.absorbed.to_string());
        md
    }

    fn recover(config: &StudyConfig, md: &Metadata) -> Result<Self, PolicyError> {
        let missing = || PolicyError::CorruptState("missing harmony memory".into());
        Ok(Self {
            config: config.clone(),
            memory: population_from_json(md.get_str("", "memory").ok_or_else(missing)?)?,
            absorbed: md
                .get_str("", "absorbed")
                .and_then(|s| s.parse().ok())
                .ok_or_else(missing)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policies::test_support::*;
    use crate::pyvizier::{Measurement, ParameterDict, TrialState};

    fn trial(id: u64, lr: f64, score: f64) -> Trial {
        let mut p = ParameterDict::new();
        p.set("lr", lr).set("layers", 2i64).set("opt", "sgd");
        let mut t = Trial::new(id, p);
        t.state = TrialState::Completed;
        t.final_measurement = Some(Measurement::new(1).with_metric("score", score));
        t
    }

    #[test]
    fn memory_keeps_best_bounded() {
        let (_, _, config) = test_study("HARMONY_SEARCH");
        let mut d = HarmonySearch::from_config(&config).unwrap();
        d.update(&(1..=50).map(|i| trial(i, 1e-3, i as f64)).collect::<Vec<_>>());
        assert_eq!(d.memory.len(), MEMORY);
        // Best-first: scores 50, 49, ...
        assert_eq!(d.memory[0].fitness(), 50.0);
        assert_eq!(d.memory.last().unwrap().fitness(), 31.0);
    }

    #[test]
    fn state_roundtrip() {
        let (_, _, config) = test_study("HARMONY_SEARCH");
        let mut d = HarmonySearch::from_config(&config).unwrap();
        d.update(&(1..=8).map(|i| trial(i, 1e-3, i as f64)).collect::<Vec<_>>());
        let d2 = HarmonySearch::recover(&config, &d.dump()).unwrap();
        assert_eq!(d2.memory, d.memory);
    }

    #[test]
    fn suggestions_feasible_and_memory_guided() {
        let (_, _, config) = test_study("HARMONY_SEARCH");
        let mut d = HarmonySearch::from_config(&config).unwrap();
        // Memory concentrated at lr=1e-2.
        d.update(&(1..=10).map(|i| trial(i, 1e-2, 10.0)).collect::<Vec<_>>());
        let suggestions = d.suggest(40).unwrap();
        let mut near = 0;
        for s in &suggestions {
            config.search_space.validate(&s.parameters).unwrap();
            if (s.parameters.get_f64("lr").unwrap().log10() + 2.0).abs() < 0.5 {
                near += 1;
            }
        }
        // ~HMCR of draws come from memory.
        assert!(near >= 25, "{near}/40 near memory values");
    }

    #[test]
    fn runs_through_designer_policy() {
        let (ds, study, config) = test_study("HARMONY_SEARCH");
        add_completed_random(&ds, &study, &config, 6);
        let s1 = run_suggest(&ds, &study, &config, 3);
        assert_eq!(s1.len(), 3);
        // Second op restores state (absorbed persists via metadata).
        let s2 = run_suggest(&ds, &study, &config, 3);
        assert_eq!(s2.len(), 3);
    }
}
