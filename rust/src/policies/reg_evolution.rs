//! Regularized evolution (Real et al., 2019) as a
//! [`SerializableDesigner`] — the paper's canonical example of an
//! algorithm that needs metadata state saving (§6.3, Code Block 7):
//! a population pool updated in O(1) per new trial, with age-based
//! (regularized) removal and tournament-selection mutation.

use super::hill_climb::mutate;
use super::population::{
    designer_rng, member_from_trial, population_from_json, population_to_json, Member,
};
use crate::pythia::designer::{Designer, SerializableDesigner};
use crate::pythia::policy::PolicyError;
use crate::pyvizier::{Metadata, StudyConfig, Trial, TrialSuggestion};

/// Population capacity.
pub const POPULATION: usize = 25;
/// Tournament size for parent selection.
pub const TOURNAMENT: usize = 5;
/// Mutation step in unit space.
const STEP: f64 = 0.1;

pub struct RegularizedEvolution {
    config: StudyConfig,
    /// FIFO population: oldest first (regularized removal kills oldest).
    population: Vec<Member>,
    /// Total trials absorbed (drives the RNG stream).
    absorbed: u64,
}

impl Designer for RegularizedEvolution {
    fn update(&mut self, completed: &[Trial]) {
        for t in completed {
            self.absorbed += 1;
            if let Some(m) = member_from_trial(t, &self.config.metrics) {
                self.population.push(m);
                if self.population.len() > POPULATION {
                    self.population.remove(0); // kill the oldest, not the worst
                }
            }
        }
    }

    fn suggest(&mut self, count: usize) -> Result<Vec<TrialSuggestion>, PolicyError> {
        let mut rng = designer_rng(&self.config, self.absorbed);
        let space = &self.config.search_space;
        Ok((0..count)
            .map(|_| {
                if self.population.is_empty() {
                    return TrialSuggestion::new(space.sample(&mut rng));
                }
                // Tournament: best of TOURNAMENT random members.
                let k = TOURNAMENT.min(self.population.len());
                let idx = rng.sample_indices(self.population.len(), k);
                let parent = idx
                    .iter()
                    .map(|&i| &self.population[i])
                    .max_by(|a, b| a.fitness().partial_cmp(&b.fitness()).unwrap())
                    .unwrap();
                TrialSuggestion::new(mutate(space, &parent.params, &mut rng, STEP))
            })
            .collect())
    }
}

impl SerializableDesigner for RegularizedEvolution {
    fn designer_name() -> &'static str {
        "regularized_evolution"
    }

    fn from_config(config: &StudyConfig) -> Result<Self, PolicyError> {
        if config.metrics.len() != 1 {
            return Err(PolicyError::Unsupported(
                "regularized evolution is single-objective (use NSGA2)".into(),
            ));
        }
        Ok(Self {
            config: config.clone(),
            population: Vec::new(),
            absorbed: 0,
        })
    }

    fn dump(&self) -> Metadata {
        let mut md = Metadata::new();
        md.put_str("", "population", &population_to_json(&self.population));
        md.put_str("", "absorbed", &self.absorbed.to_string());
        md
    }

    fn recover(config: &StudyConfig, md: &Metadata) -> Result<Self, PolicyError> {
        let missing = || PolicyError::CorruptState("missing population key".into());
        let population = population_from_json(md.get_str("", "population").ok_or_else(missing)?)?;
        let absorbed = md
            .get_str("", "absorbed")
            .and_then(|s| s.parse().ok())
            .ok_or_else(missing)?;
        Ok(Self {
            config: config.clone(),
            population,
            absorbed,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policies::test_support::*;
    use crate::pyvizier::{Measurement, ParameterDict, TrialState};

    fn completed_trial(id: u64, lr: f64, score: f64) -> Trial {
        let mut p = ParameterDict::new();
        p.set("lr", lr).set("layers", 3i64).set("opt", "adam");
        let mut t = Trial::new(id, p);
        t.state = TrialState::Completed;
        t.final_measurement = Some(Measurement::new(1).with_metric("score", score));
        t
    }

    #[test]
    fn population_is_age_bounded() {
        let (_, _, config) = test_study("REGULARIZED_EVOLUTION");
        let mut d = RegularizedEvolution::from_config(&config).unwrap();
        let trials: Vec<Trial> =
            (1..=40).map(|i| completed_trial(i, 0.01, i as f64)).collect();
        d.update(&trials);
        assert_eq!(d.population.len(), POPULATION);
        // Oldest removed: ids 16..=40 remain.
        assert_eq!(d.population[0].id, 16);
    }

    #[test]
    fn dump_recover_preserves_population() {
        let (_, _, config) = test_study("REGULARIZED_EVOLUTION");
        let mut d = RegularizedEvolution::from_config(&config).unwrap();
        d.update(&(1..=10).map(|i| completed_trial(i, 0.02, i as f64)).collect::<Vec<_>>());
        let md = d.dump();
        let d2 = RegularizedEvolution::recover(&config, &md).unwrap();
        assert_eq!(d2.population, d.population);
        assert_eq!(d2.absorbed, 10);
    }

    #[test]
    fn suggestions_feasible_and_exploit_fit_parents() {
        let (_, _, config) = test_study("REGULARIZED_EVOLUTION");
        let mut d = RegularizedEvolution::from_config(&config).unwrap();
        // One excellent member at lr=1e-2 and many poor ones at 1e-4.
        let mut trials = vec![completed_trial(1, 1e-2, 100.0)];
        trials.extend((2..=10).map(|i| completed_trial(i, 1e-4, 0.0)));
        d.update(&trials);
        let suggestions = d.suggest(30).unwrap();
        let near_best = suggestions
            .iter()
            .filter(|s| {
                config.search_space.validate(&s.parameters).unwrap();
                (s.parameters.get_f64("lr").unwrap().log10() + 2.0).abs() < 1.0
            })
            .count();
        // Tournament of 5 over 10 members picks the best with p ~ 0.5+.
        assert!(near_best >= 12, "{near_best}/30 near the fit parent");
    }

    #[test]
    fn end_to_end_improves_over_random_start() {
        let (ds, study, config) = test_study("REGULARIZED_EVOLUTION");
        // Warm start with random completions, then run the designer loop.
        add_completed_random(&ds, &study, &config, 10);
        let mut best = f64::NEG_INFINITY;
        for _ in 0..20 {
            let sugg = run_suggest(&ds, &study, &config, 2);
            for s in sugg {
                let id = add_completed_with(&ds, &study, &config, s.parameters.clone());
                let _ = id;
                best = best.max(score_of(&s.parameters));
            }
        }
        // Optimum is score = 0.2 (lr=1e-2, layers=3, adam); evolution should
        // get close while pure random rarely does in 40 samples.
        assert!(best > -0.35, "best {best}");
    }

    #[test]
    fn rejects_multiobjective() {
        let (_, _, mut config) = test_study("REGULARIZED_EVOLUTION");
        config.add_metric(crate::pyvizier::MetricInformation::minimize("latency"));
        assert!(matches!(
            RegularizedEvolution::from_config(&config),
            Err(PolicyError::Unsupported(_))
        ));
    }
}
