//! Shared population machinery for the evolutionary designers
//! (regularized evolution, NSGA-II, harmony search, firefly): members,
//! JSON (de)serialization for metadata state dumps, and trial ingestion.

use crate::pythia::policy::PolicyError;
use crate::pyvizier::converters::{params_from_json, params_to_json};
use crate::pyvizier::{MetricInformation, ParameterDict, StudyConfig, Trial};
use crate::util::json::{parse, Json};

/// One population member: an evaluated point.
#[derive(Debug, Clone, PartialEq)]
pub struct Member {
    /// Trial id the member came from (0 = synthetic/seeded).
    pub id: u64,
    pub params: ParameterDict,
    /// Objective vector in maximization orientation.
    pub values: Vec<f64>,
}

impl Member {
    pub fn fitness(&self) -> f64 {
        self.values[0]
    }
}

/// Extract a member from a completed trial (None if metrics missing or the
/// trial is infeasible — infeasible lifts are excluded from populations).
pub fn member_from_trial(t: &Trial, metrics: &[MetricInformation]) -> Option<Member> {
    if !t.is_feasible_completed() {
        return None;
    }
    let values = crate::pyvizier::pareto::objective_vector(t, metrics)?;
    Some(Member {
        id: t.id,
        params: t.parameters.clone(),
        values,
    })
}

/// Serialize a population to a JSON string for a metadata dump.
pub fn population_to_json(members: &[Member]) -> String {
    Json::Arr(
        members
            .iter()
            .map(|m| {
                let mut o = Json::obj();
                o.set("id", Json::Num(m.id as f64));
                o.set("params", params_to_json(&m.params));
                o.set("values", Json::Arr(m.values.iter().map(|&v| Json::Num(v)).collect()));
                o
            })
            .collect(),
    )
    .to_string()
}

/// Restore a population; any malformed entry makes the whole decode fail
/// (the designer wrapper then rebuilds from trials — "harmless" error).
pub fn population_from_json(s: &str) -> Result<Vec<Member>, PolicyError> {
    let corrupt = |m: &str| PolicyError::CorruptState(m.to_string());
    let doc = parse(s).map_err(|e| corrupt(&e.to_string()))?;
    let arr = doc.as_arr().ok_or_else(|| corrupt("expected array"))?;
    arr.iter()
        .map(|item| {
            let id = item
                .get("id")
                .and_then(|j| j.as_i64())
                .ok_or_else(|| corrupt("missing id"))? as u64;
            let params = item
                .get("params")
                .and_then(params_from_json)
                .ok_or_else(|| corrupt("bad params"))?;
            let values = item
                .get("values")
                .and_then(|j| j.as_arr())
                .ok_or_else(|| corrupt("missing values"))?
                .iter()
                .map(|v| v.as_f64().ok_or_else(|| corrupt("bad value")))
                .collect::<Result<Vec<f64>, _>>()?;
            if values.is_empty() {
                return Err(corrupt("empty objective vector"));
            }
            Ok(Member { id, params, values })
        })
        .collect()
}

/// Derive a designer RNG whose stream advances with the population so
/// successive operations explore fresh randomness but crash-replays of the
/// same state are deterministic.
pub fn designer_rng(config: &StudyConfig, absorbed: u64) -> crate::util::rng::Pcg32 {
    let seed = if config.seed != 0 { config.seed } else { 0x5eed };
    crate::util::rng::Pcg32::new(seed, absorbed.wrapping_add(17))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pyvizier::{Measurement, TrialState};
    use crate::testing::prop::check;

    #[test]
    fn prop_population_json_roundtrip() {
        check("population json roundtrip", 100, |g| {
            let members: Vec<Member> = (0..g.usize_range(0, 8))
                .map(|i| {
                    let mut p = ParameterDict::new();
                    p.set("x", g.f64_range(-10.0, 10.0));
                    p.set("c", g.ident(4));
                    Member {
                        id: i as u64,
                        params: p,
                        values: (0..g.usize_range(1, 3)).map(|_| g.f64_range(-5.0, 5.0)).collect(),
                    }
                })
                .collect();
            let s = population_to_json(&members);
            let back = population_from_json(&s).unwrap();
            assert_eq!(back, members);
        });
    }

    #[test]
    fn corrupt_json_is_explicit_error() {
        assert!(population_from_json("not json").is_err());
        assert!(population_from_json("{\"not\": \"array\"}").is_err());
        assert!(population_from_json("[{\"id\": 1}]").is_err());
    }

    #[test]
    fn member_extraction_rules() {
        let metrics = vec![MetricInformation::minimize("loss")];
        let mut t = Trial::new(3, ParameterDict::new());
        assert!(member_from_trial(&t, &metrics).is_none(), "active trial skipped");
        t.state = TrialState::Completed;
        t.final_measurement = Some(Measurement::new(1).with_metric("loss", 2.0));
        let m = member_from_trial(&t, &metrics).unwrap();
        assert_eq!(m.values, vec![-2.0], "minimize negated");
        t.infeasibility_reason = Some("bad".into());
        assert!(member_from_trial(&t, &metrics).is_none(), "infeasible skipped");
    }
}
