//! Built-in optimization policies.
//!
//! The paper intentionally ships no proprietary default algorithm (§8);
//! its algorithm surface is defined by Code Block 2 (GP-bandit) and §6.3's
//! evolutionary/local-search families (NSGA-II, Firefly, Harmony Search).
//! This module implements that surface:
//!
//! | Algorithm                | Module          | Kind                      |
//! |--------------------------|-----------------|---------------------------|
//! | `RANDOM_SEARCH`          | [`random`]      | stateless                 |
//! | `GRID_SEARCH`            | [`grid`]        | stateless (index-driven)  |
//! | `QUASI_RANDOM_SEARCH`    | [`quasirandom`] | stateless (Halton)        |
//! | `HILL_CLIMB`             | [`hill_climb`]  | local search              |
//! | `REGULARIZED_EVOLUTION`  | [`reg_evolution`]| SerializableDesigner     |
//! | `NSGA2`                  | [`nsga2`]       | SerializableDesigner, MO  |
//! | `HARMONY_SEARCH`         | [`harmony`]     | SerializableDesigner      |
//! | `FIREFLY`                | [`firefly`]     | SerializableDesigner      |
//! | `GP_BANDIT`              | [`gp_bandit`]   | Bayesian opt (Code Blk 2) |

pub mod population;
pub mod firefly;
pub mod gp_bandit;
pub mod gp_math;
pub mod grid;
pub mod harmony;
pub mod hill_climb;
pub mod nsga2;
pub mod quasirandom;
pub mod random;
pub mod reg_evolution;

use crate::pythia::designer::DesignerPolicy;
use crate::pythia::runner::PolicyRegistry;
use std::sync::Arc;

/// Register every built-in policy under its canonical algorithm name.
pub fn register_builtins(registry: &mut PolicyRegistry) {
    registry.register("RANDOM_SEARCH", Arc::new(|_| Box::new(random::RandomSearchPolicy)));
    registry.register("GRID_SEARCH", Arc::new(|_| Box::new(grid::GridSearchPolicy)));
    registry.register(
        "QUASI_RANDOM_SEARCH",
        Arc::new(|_| Box::new(quasirandom::QuasiRandomPolicy)),
    );
    registry.register("HILL_CLIMB", Arc::new(|_| Box::new(hill_climb::HillClimbPolicy)));
    registry.register(
        "REGULARIZED_EVOLUTION",
        Arc::new(|_| Box::new(DesignerPolicy::<reg_evolution::RegularizedEvolution>::new())),
    );
    registry.register(
        "NSGA2",
        Arc::new(|_| Box::new(DesignerPolicy::<nsga2::Nsga2Designer>::new())),
    );
    registry.register(
        "HARMONY_SEARCH",
        Arc::new(|_| Box::new(DesignerPolicy::<harmony::HarmonySearch>::new())),
    );
    registry.register(
        "FIREFLY",
        Arc::new(|_| Box::new(DesignerPolicy::<firefly::FireflyDesigner>::new())),
    );
    // GP_BANDIT prefers the AOT-compiled JAX/Pallas artifact (PJRT) and
    // falls back to the pure-Rust GP when `make artifacts` has not run.
    registry.register(
        "GP_BANDIT",
        Arc::new(|_| match crate::runtime::GpArtifactBackend::from_global() {
            Some(b) => Box::new(gp_bandit::GpBanditPolicy::with_backend(Arc::new(b))),
            None => Box::new(gp_bandit::GpBanditPolicy::default()),
        }),
    );
    // Explicit pure-Rust backend (parity tests and ablation benches).
    registry.register(
        "GP_BANDIT_RUST",
        Arc::new(|_| Box::new(gp_bandit::GpBanditPolicy::default())),
    );
}

/// Derive a deterministic per-operation RNG for a policy: stable in
/// (study seed, study name, #existing trials), so replaying an operation
/// after a crash yields the same suggestions, while successive operations
/// explore fresh randomness.
pub(crate) fn op_rng(
    config: &crate::pyvizier::StudyConfig,
    study_name: &str,
    salt: u64,
) -> crate::util::rng::Pcg32 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in study_name.bytes() {
        h = (h ^ b as u64).wrapping_mul(0x1000_0000_01b3);
    }
    let seed = if config.seed != 0 { config.seed } else { h };
    crate::util::rng::Pcg32::new(seed ^ h, salt.wrapping_add(1))
}

#[cfg(test)]
pub(crate) mod test_support {
    //! Shared fixtures for policy tests.
    use crate::datastore::memory::InMemoryDatastore;
    use crate::datastore::Datastore;
    use crate::pythia::runner::{default_registry, LocalPythia, PythiaEndpoint};
    use crate::pythia::policy::SuggestRequest;
    use crate::pythia::supporter::{DatastoreSupporter, PolicySupporter};
    use crate::pyvizier::{
        converters, Algorithm, Measurement, MetricInformation, StudyConfig, TrialSuggestion,
    };
    use crate::wire::messages::{ScaleType, StudyProto, TrialState};
    use std::sync::Arc;

    /// A standard single-objective study: log-float + int + categorical.
    pub fn test_study(algorithm: &str) -> (Arc<InMemoryDatastore>, String, StudyConfig) {
        let mut config = StudyConfig::new("test-study");
        config
            .search_space
            .add_float("lr", 1e-4, 1e-1, ScaleType::Log)
            .add_int("layers", 1, 5)
            .add_categorical("opt", vec!["sgd", "adam", "rmsprop"]);
        config.add_metric(MetricInformation::maximize("score"));
        config.algorithm = Algorithm::from_str(algorithm);
        config.seed = 42;
        let ds = Arc::new(InMemoryDatastore::new());
        let study = ds
            .create_study(StudyProto {
                display_name: "test-study".into(),
                spec: converters::study_config_to_proto(&config),
                ..Default::default()
            })
            .unwrap();
        (ds, study.name, config)
    }

    /// Run one suggest operation via the full Pythia path, persisting any
    /// returned designer metadata (as the service does).
    pub fn run_suggest(
        ds: &Arc<InMemoryDatastore>,
        study: &str,
        config: &StudyConfig,
        count: usize,
    ) -> Vec<TrialSuggestion> {
        let supporter = Arc::new(DatastoreSupporter::new(
            Arc::clone(ds) as Arc<dyn Datastore>
        ));
        let pythia = LocalPythia::new(default_registry(), supporter.clone());
        // Refresh config from store so designer metadata round-trips.
        let fresh_config = supporter.study_config(study).unwrap();
        let decision = pythia
            .run_suggest(&SuggestRequest::single(
                study,
                StudyConfig {
                    algorithm: config.algorithm.clone(),
                    ..fresh_config
                },
                "test-client",
                count,
            ))
            .unwrap();
        // Apply the unified delta the way the service does (study- and
        // trial-level writes in one atomic batch).
        if !decision.metadata_delta.is_empty() {
            ds.update_metadata(study, &decision.metadata_delta.to_updates())
                .unwrap();
        }
        decision.flatten()
    }

    /// Complete `n` random trials with a synthetic objective: score =
    /// -(log10(lr) + 2)^2 - 0.1*(layers - 3)^2 (+ bonus for adam), so
    /// policies have a real signal to exploit.
    pub fn add_completed_random(
        ds: &Arc<InMemoryDatastore>,
        study: &str,
        config: &StudyConfig,
        n: usize,
    ) {
        let mut rng = crate::util::rng::Pcg32::seeded(7 + n as u64);
        for _ in 0..n {
            let params = config.search_space.sample(&mut rng);
            add_completed_with(ds, study, config, params);
        }
    }

    pub fn score_of(params: &crate::pyvizier::ParameterDict) -> f64 {
        let lr = params.get_f64("lr").unwrap_or(1e-2);
        let layers = params.get_i64("layers").unwrap_or(3) as f64;
        let bonus = if params.get_str("opt") == Some("adam") { 0.2 } else { 0.0 };
        -(lr.log10() + 2.0).powi(2) - 0.1 * (layers - 3.0).powi(2) + bonus
    }

    pub fn add_completed_with(
        ds: &Arc<InMemoryDatastore>,
        study: &str,
        config: &StudyConfig,
        params: crate::pyvizier::ParameterDict,
    ) -> u64 {
        let _ = config;
        let score = score_of(&params);
        let mut trial = crate::pyvizier::Trial::new(0, params);
        trial.state = TrialState::Completed;
        trial.final_measurement = Some(Measurement::new(1).with_metric("score", score));
        let proto = converters::trial_to_proto(&trial);
        let created = ds.create_trial(study, proto).unwrap();
        created.id
    }
}

/// A smooth synthetic objective over the (lr, layers, opt) test space —
/// shared by tests and benches: peak 0.2 at lr=1e-2, layers=3, opt=adam.
pub fn test_objective_score(params: &crate::pyvizier::ParameterDict) -> f64 {
    let lr = params.get_f64("lr").unwrap_or(1e-2);
    let layers = params.get_i64("layers").unwrap_or(3) as f64;
    let bonus = if params.get_str("opt") == Some("adam") { 0.2 } else { 0.0 };
    -(lr.log10() + 2.0).powi(2) - 0.1 * (layers - 3.0).powi(2) + bonus
}
