//! Gaussian-Process bandit policy (paper Code Block 2):
//! "train a GP on the completed trials, use it to compute and optimize an
//! acquisition function, and return the suggestion".
//!
//! The numeric core is pluggable via [`GpBackend`]: [`RustGpBackend`] runs
//! the pure-Rust math in [`super::gp_math`]; `runtime::gp_artifact`
//! provides the AOT-compiled JAX/Pallas version executed through PJRT
//! (same interface, validated against this one in integration tests).
//! Acquisition optimization is batched scoring over quasi-random
//! candidates with a local-refinement pass.

use super::firefly::{from_unit_value, to_unit_value};
use super::gp_math::{GpParams, GpPosterior};
use super::quasirandom::halton;
use crate::datastore::query::TrialFilter;
use crate::pythia::policy::{Policy, PolicyError, SuggestDecision, SuggestRequest};
use crate::pythia::supporter::PolicySupporter;
use crate::pyvizier::{ObservationNoise, ParameterDict, StudyConfig, TrialSuggestion};
use crate::util::rng::Pcg32;
use std::sync::Arc;

/// Number of quasi-random acquisition candidates scored per suggestion.
pub const CANDIDATES: usize = 256;
/// UCB exploration coefficient.
pub const UCB_BETA: f64 = 2.0;
/// Seed trials before the GP engages.
pub const MIN_OBSERVATIONS: usize = 4;
/// Cap on training-set size: the newest N completed trials are used
/// (keeps the O(n^3) solve bounded; matches the padded AOT artifact).
pub const MAX_TRAIN: usize = 256;

/// Backend interface: score `candidates` (unit-cube rows) given training
/// data (unit-cube rows + raw objective values, maximization orientation).
/// Returns one acquisition score per candidate (higher = better).
pub trait GpBackend: Send + Sync {
    fn score(
        &self,
        x_train: &[Vec<f64>],
        y_train: &[f64],
        candidates: &[Vec<f64>],
        noise_high: bool,
    ) -> Result<Vec<f64>, PolicyError>;

    fn backend_name(&self) -> &str;
}

/// Pure-Rust backend.
pub struct RustGpBackend;

impl GpBackend for RustGpBackend {
    fn score(
        &self,
        x_train: &[Vec<f64>],
        y_train: &[f64],
        candidates: &[Vec<f64>],
        noise_high: bool,
    ) -> Result<Vec<f64>, PolicyError> {
        let gp = GpPosterior::fit(
            x_train.to_vec(),
            y_train,
            GpParams::default().with_noise_hint(noise_high),
        )
        .map_err(PolicyError::Internal)?;
        Ok(candidates.iter().map(|c| gp.ucb(c, UCB_BETA)).collect())
    }

    fn backend_name(&self) -> &str {
        "rust-gp"
    }
}

/// The GP-bandit policy.
pub struct GpBanditPolicy {
    backend: Arc<dyn GpBackend>,
}

impl Default for GpBanditPolicy {
    fn default() -> Self {
        Self {
            backend: Arc::new(RustGpBackend),
        }
    }
}

impl GpBanditPolicy {
    /// Use a custom numeric backend (e.g. the PJRT artifact executor).
    pub fn with_backend(backend: Arc<dyn GpBackend>) -> Self {
        Self { backend }
    }
}

/// Map an assignment to unit-cube coordinates over the flattened configs.
pub fn embed(config: &StudyConfig, params: &ParameterDict) -> Vec<f64> {
    config
        .search_space
        .all_configs()
        .iter()
        .map(|cfg| match params.get(&cfg.name) {
            Some(v) => to_unit_value(cfg, v),
            None => 0.5, // inactive conditional branch: neutral coordinate
        })
        .collect()
}

/// Map unit-cube coordinates back to a feasible assignment.
pub fn unembed(config: &StudyConfig, point: &[f64]) -> ParameterDict {
    let configs = config.search_space.all_configs();
    let units: std::collections::HashMap<String, f64> = configs
        .iter()
        .zip(point)
        .map(|(c, &u)| (c.name.clone(), u))
        .collect();
    config
        .search_space
        .assemble(|cfg| from_unit_value(cfg, units.get(&cfg.name).copied().unwrap_or(0.5)))
}

impl Policy for GpBanditPolicy {
    fn suggest(
        &mut self,
        req: &SuggestRequest,
        supporter: &dyn PolicySupporter,
    ) -> Result<SuggestDecision, PolicyError> {
        let config = &req.study_config;
        if config.metrics.len() != 1 {
            return Err(PolicyError::Unsupported(
                "GP_BANDIT is single-objective; use NSGA2 for multi-objective studies".into(),
            ));
        }
        let metric = config.single_objective();
        let total = supporter.trial_count(&req.study_name)? as u64;
        let mut rng = super::op_rng(config, &req.study_name, total);

        // Training data: newest MAX_TRAIN completed feasible trials.
        let completed = supporter.trials(
            &req.study_name,
            &TrialFilter::completed().with_limit(MAX_TRAIN),
        )?;
        let mut x_train = Vec::new();
        let mut y_train = Vec::new();
        for t in &completed {
            if !t.is_feasible_completed() {
                continue;
            }
            if let Some(v) = t.final_metric(&metric.name) {
                x_train.push(embed(config, &t.parameters));
                y_train.push(metric.maximization_value(v));
            }
        }

        // One GP fit serves the whole coalesced batch — with K wants this
        // is the K-fits-to-one saving the v2 batching exists for.
        let batch = req.total_count();

        // Cold start: quasi-random seeding.
        if x_train.len() < MIN_OBSERVATIONS {
            let suggestions = (0..batch as u64)
                .map(|i| {
                    TrialSuggestion::new(super::quasirandom::halton_point(
                        &config.search_space,
                        total + i,
                    ))
                })
                .collect();
            return Ok(SuggestDecision::from_flat(req, suggestions));
        }

        let noise_high = config.observation_noise == ObservationNoise::High;
        let dims = config.search_space.all_configs().len();
        let mut suggestions = Vec::with_capacity(batch);
        for b in 0..batch {
            // Candidate pool: Halton net + jittered perturbations of the
            // incumbent (local refinement).
            let mut candidates: Vec<Vec<f64>> = (0..CANDIDATES as u64 * 3 / 4)
                .map(|i| halton(total * 31 + b as u64 * 977 + i + 20, dims))
                .collect();
            let best_idx = y_train
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap();
            let incumbent = &x_train[best_idx];
            while candidates.len() < CANDIDATES {
                let jittered: Vec<f64> = incumbent
                    .iter()
                    .map(|&u| (u + rng.normal() * 0.05).clamp(0.0, 1.0))
                    .collect();
                candidates.push(jittered);
            }

            let scores = self
                .backend
                .score(&x_train, &y_train, &candidates, noise_high)?;
            let pick = scores
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
                .map(|(i, _)| i)
                .ok_or_else(|| PolicyError::Internal("no candidates scored".into()))?;

            // Within-batch diversity: pretend the pick was observed at the
            // incumbent's value ("constant liar") so the next batch member
            // explores elsewhere.
            let lie = y_train[best_idx];
            x_train.push(candidates[pick].clone());
            y_train.push(lie);
            suggestions.push(TrialSuggestion::new(unembed(config, &candidates[pick])));
        }
        Ok(SuggestDecision::from_flat(req, suggestions))
    }

    fn name(&self) -> &str {
        "gp-bandit"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policies::test_support::*;

    #[test]
    fn cold_start_uses_quasirandom() {
        let (ds, study, config) = test_study("GP_BANDIT");
        let s = run_suggest(&ds, &study, &config, 4);
        assert_eq!(s.len(), 4);
        for sg in &s {
            config.search_space.validate(&sg.parameters).unwrap();
        }
    }

    #[test]
    fn embedding_roundtrip_feasible() {
        let (_, _, config) = test_study("GP_BANDIT");
        let mut rng = crate::util::rng::Pcg32::seeded(2);
        for _ in 0..50 {
            let p = config.search_space.sample(&mut rng);
            let e = embed(&config, &p);
            assert_eq!(e.len(), 3);
            assert!(e.iter().all(|&u| (0.0..=1.0).contains(&u)));
            let back = unembed(&config, &e);
            config.search_space.validate(&back).unwrap();
        }
    }

    #[test]
    fn exploits_signal_after_warmup() {
        let (ds, study, config) = test_study("GP_BANDIT");
        // Warm up with informative observations.
        add_completed_random(&ds, &study, &config, 12);
        // Several bandit rounds.
        let mut best = f64::NEG_INFINITY;
        for _ in 0..8 {
            let sugg = run_suggest(&ds, &study, &config, 2);
            for s in sugg {
                config.search_space.validate(&s.parameters).unwrap();
                best = best.max(score_of(&s.parameters));
                add_completed_with(&ds, &study, &config, s.parameters.clone());
            }
        }
        // Optimum score is 0.2; GP should find a good region quickly.
        assert!(best > -0.4, "best found {best}");
    }

    #[test]
    fn batch_members_are_diverse() {
        let (ds, study, config) = test_study("GP_BANDIT");
        add_completed_random(&ds, &study, &config, 10);
        let s = run_suggest(&ds, &study, &config, 4);
        let distinct: std::collections::HashSet<String> =
            s.iter().map(|x| format!("{:?}", x.parameters)).collect();
        assert!(distinct.len() >= 3, "batch should not collapse to one point");
    }

    #[test]
    fn rejects_multiobjective() {
        let (ds, study, mut config) = test_study("GP_BANDIT");
        config.add_metric(crate::pyvizier::MetricInformation::minimize("x"));
        let supporter = std::sync::Arc::new(crate::pythia::supporter::DatastoreSupporter::new(
            ds as std::sync::Arc<dyn crate::datastore::Datastore>,
        ));
        let mut policy = GpBanditPolicy::default();
        let err = policy
            .suggest(
                &crate::pythia::policy::SuggestRequest::single(study, config, "c", 1),
                supporter.as_ref(),
            )
            .unwrap_err();
        assert!(matches!(err, PolicyError::Unsupported(_)));
    }
}
