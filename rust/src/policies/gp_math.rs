//! Pure-Rust Gaussian-process regression: Matérn-5/2 kernel, Cholesky
//! factorization, posterior mean/variance, and UCB/EI acquisitions.
//!
//! This is the reference implementation of the GP-bandit numeric core. It
//! serves three roles: (1) the fallback backend for
//! [`super::gp_bandit::GpBanditPolicy`] when no AOT artifact is available,
//! (2) the oracle the PJRT artifact is validated against in integration
//! tests, and (3) the regressor behind decay-curve automated stopping
//! (Appendix B.1). The JAX/Pallas layers (python/compile/) implement the
//! same math; python/compile/kernels/ref.py mirrors these formulas.

/// Row-major dense matrix of f64.
#[derive(Debug, Clone)]
pub struct Mat {
    pub n: usize,
    pub m: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(n: usize, m: usize) -> Self {
        Self {
            n,
            m,
            data: vec![0.0; n * m],
        }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.m + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.m + j] = v;
    }
}

/// Squared Euclidean distance between two points scaled by 1/lengthscale.
#[inline]
fn scaled_sqdist(a: &[f64], b: &[f64], inv_ls: f64) -> f64 {
    a.iter()
        .zip(b)
        .map(|(x, y)| {
            let d = (x - y) * inv_ls;
            d * d
        })
        .sum()
}

/// Matérn-5/2 kernel value given squared scaled distance.
#[inline]
pub fn matern52(r2: f64, sigma2: f64) -> f64 {
    let r = r2.max(0.0).sqrt();
    let s5r = 5.0f64.sqrt() * r;
    sigma2 * (1.0 + s5r + 5.0 * r2 / 3.0) * (-s5r).exp()
}

/// Kernel matrix K[i][j] = matern52(|x_i - x_j|/ls) for rows of X vs rows
/// of Y. This is the computation the L1 Pallas kernel tiles on TPU.
pub fn kernel_matrix(x: &[Vec<f64>], y: &[Vec<f64>], lengthscale: f64, sigma2: f64) -> Mat {
    let inv_ls = 1.0 / lengthscale;
    let mut k = Mat::zeros(x.len(), y.len());
    for i in 0..x.len() {
        for j in 0..y.len() {
            k.set(i, j, matern52(scaled_sqdist(&x[i], &y[j], inv_ls), sigma2));
        }
    }
    k
}

/// In-place Cholesky factorization A = L Lᵀ (lower triangular returned).
/// Adds escalating jitter on failure; errors if even large jitter fails.
pub fn cholesky(a: &Mat) -> Result<Mat, String> {
    let n = a.n;
    assert_eq!(a.n, a.m, "cholesky needs a square matrix");
    let mut jitter = 0.0;
    'attempt: for attempt in 0..6 {
        let mut l = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let mut sum = a.at(i, j) + if i == j { jitter } else { 0.0 };
                for k in 0..j {
                    sum -= l.at(i, k) * l.at(j, k);
                }
                if i == j {
                    if sum <= 0.0 {
                        jitter = if attempt == 0 { 1e-10 } else { jitter * 100.0 };
                        continue 'attempt;
                    }
                    l.set(i, j, sum.sqrt());
                } else {
                    l.set(i, j, sum / l.at(j, j));
                }
            }
        }
        return Ok(l);
    }
    Err("matrix not positive definite even with jitter".to_string())
}

/// Solve L z = b (forward substitution).
pub fn solve_lower(l: &Mat, b: &[f64]) -> Vec<f64> {
    let n = l.n;
    let mut z = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l.at(i, k) * z[k];
        }
        z[i] = s / l.at(i, i);
    }
    z
}

/// Solve Lᵀ x = b (backward substitution).
pub fn solve_upper_t(l: &Mat, b: &[f64]) -> Vec<f64> {
    let n = l.n;
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = b[i];
        for k in i + 1..n {
            s -= l.at(k, i) * x[k];
        }
        x[i] = s / l.at(i, i);
    }
    x
}

/// A fitted GP posterior.
pub struct GpPosterior {
    x_train: Vec<Vec<f64>>,
    l: Mat,
    alpha: Vec<f64>,
    lengthscale: f64,
    sigma2: f64,
    y_mean: f64,
    y_std: f64,
}

/// GP hyperparameters (fixed; the paper's service leaves hyperparameter
/// policy to the algorithm author).
#[derive(Debug, Clone, Copy)]
pub struct GpParams {
    pub lengthscale: f64,
    pub sigma2: f64,
    pub noise: f64,
}

impl Default for GpParams {
    fn default() -> Self {
        Self {
            lengthscale: 0.25,
            sigma2: 1.0,
            noise: 1e-6,
        }
    }
}

impl GpParams {
    /// Apply the observation-noise hint of Appendix B.2.
    pub fn with_noise_hint(mut self, high: bool) -> Self {
        self.noise = if high { 1e-2 } else { 1e-6 };
        self
    }
}

impl GpPosterior {
    /// Fit on (x, y); x rows are unit-cube coordinates, y raw objective
    /// values (standardized internally).
    pub fn fit(x: Vec<Vec<f64>>, y: &[f64], p: GpParams) -> Result<Self, String> {
        assert_eq!(x.len(), y.len());
        assert!(!x.is_empty(), "GP needs at least one observation");
        let n = x.len();
        let y_mean = y.iter().sum::<f64>() / n as f64;
        let var = y.iter().map(|v| (v - y_mean) * (v - y_mean)).sum::<f64>() / n as f64;
        let y_std = var.sqrt().max(1e-12);
        let y_norm: Vec<f64> = y.iter().map(|v| (v - y_mean) / y_std).collect();

        let mut k = kernel_matrix(&x, &x, p.lengthscale, p.sigma2);
        for i in 0..n {
            let v = k.at(i, i) + p.noise;
            k.set(i, i, v);
        }
        let l = cholesky(&k)?;
        let z = solve_lower(&l, &y_norm);
        let alpha = solve_upper_t(&l, &z);
        Ok(Self {
            x_train: x,
            l,
            alpha,
            lengthscale: p.lengthscale,
            sigma2: p.sigma2,
            y_mean,
            y_std,
        })
    }

    /// Posterior mean and variance at one point (in the original y scale).
    pub fn predict(&self, x: &[f64]) -> (f64, f64) {
        let inv_ls = 1.0 / self.lengthscale;
        let kstar: Vec<f64> = self
            .x_train
            .iter()
            .map(|xi| matern52(scaled_sqdist(xi, x, inv_ls), self.sigma2))
            .collect();
        let mean_n: f64 = kstar.iter().zip(&self.alpha).map(|(k, a)| k * a).sum();
        let v = solve_lower(&self.l, &kstar);
        let var_n = (self.sigma2 - v.iter().map(|x| x * x).sum::<f64>()).max(1e-12);
        (
            self.y_mean + self.y_std * mean_n,
            (self.y_std * self.y_std) * var_n,
        )
    }

    /// Upper confidence bound acquisition.
    pub fn ucb(&self, x: &[f64], beta: f64) -> f64 {
        let (mu, var) = self.predict(x);
        mu + beta * var.sqrt()
    }

    /// Expected improvement over `best` (maximization).
    pub fn expected_improvement(&self, x: &[f64], best: f64) -> f64 {
        let (mu, var) = self.predict(x);
        let sigma = var.sqrt();
        if sigma < 1e-12 {
            return (mu - best).max(0.0);
        }
        let z = (mu - best) / sigma;
        (mu - best) * normal_cdf(z) + sigma * normal_pdf(z)
    }
}

fn normal_pdf(z: f64) -> f64 {
    (-0.5 * z * z).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

/// Φ(z) via the Abramowitz–Stegun erf approximation (|err| < 1.5e-7).
pub fn normal_cdf(z: f64) -> f64 {
    0.5 * (1.0 + erf(z / std::f64::consts::SQRT_2))
}

fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::check;

    #[test]
    fn kernel_properties() {
        // k(0) = sigma2; symmetric; decreasing in distance.
        assert!((matern52(0.0, 2.0) - 2.0).abs() < 1e-12);
        assert!(matern52(0.1, 1.0) > matern52(1.0, 1.0));
        let x = vec![vec![0.0, 0.0], vec![1.0, 0.5], vec![0.3, 0.9]];
        let k = kernel_matrix(&x, &x, 0.5, 1.0);
        for i in 0..3 {
            for j in 0..3 {
                assert!((k.at(i, j) - k.at(j, i)).abs() < 1e-12);
            }
            assert!((k.at(i, i) - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn cholesky_reconstructs() {
        // A = B Bᵀ + I is SPD for any B.
        let b = Mat {
            n: 4,
            m: 4,
            data: vec![
                1.0, 0.2, -0.5, 0.0, 0.3, 2.0, 0.1, -0.7, 0.0, 0.4, 1.5, 0.2, -0.1, 0.0, 0.3, 0.9,
            ],
        };
        let mut a = Mat::zeros(4, 4);
        for i in 0..4 {
            for j in 0..4 {
                let mut s = if i == j { 1.0 } else { 0.0 };
                for k in 0..4 {
                    s += b.at(i, k) * b.at(j, k);
                }
                a.set(i, j, s);
            }
        }
        let l = cholesky(&a).unwrap();
        for i in 0..4 {
            for j in 0..4 {
                let mut s = 0.0;
                for k in 0..4 {
                    s += l.at(i, k) * l.at(j, k);
                }
                assert!((s - a.at(i, j)).abs() < 1e-9, "({i},{j})");
            }
        }
    }

    #[test]
    fn triangular_solves_invert() {
        let a = Mat {
            n: 3,
            m: 3,
            data: vec![4.0, 1.0, 0.5, 1.0, 3.0, 0.2, 0.5, 0.2, 2.0],
        };
        let l = cholesky(&a).unwrap();
        let b = vec![1.0, -2.0, 0.5];
        let z = solve_lower(&l, &b);
        let x = solve_upper_t(&l, &z);
        // Check A x = b.
        for i in 0..3 {
            let s: f64 = (0..3).map(|j| a.at(i, j) * x[j]).sum();
            assert!((s - b[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn posterior_interpolates_observations() {
        // Noise-free GP must (nearly) interpolate training points.
        let x = vec![vec![0.1], vec![0.5], vec![0.9]];
        let y = vec![1.0, -1.0, 0.5];
        let gp = GpPosterior::fit(x.clone(), &y, GpParams::default()).unwrap();
        for (xi, yi) in x.iter().zip(&y) {
            let (mu, var) = gp.predict(xi);
            assert!((mu - yi).abs() < 1e-3, "mean at train point: {mu} vs {yi}");
            assert!(var < 1e-3, "variance at train point: {var}");
        }
        // Far from data: variance grows toward prior.
        let (_, var_far) = gp.predict(&[3.0]);
        assert!(var_far > 0.5);
    }

    #[test]
    fn noise_hint_changes_fit(){
        let x = vec![vec![0.2], vec![0.2001], vec![0.8]];
        let y = vec![0.0, 1.0, 0.5]; // conflicting near-duplicates
        let low = GpPosterior::fit(x.clone(), &y, GpParams::default().with_noise_hint(false));
        let high = GpPosterior::fit(x, &y, GpParams::default().with_noise_hint(true)).unwrap();
        // High noise smooths the conflict: prediction between 0 and 1.
        let (mu, _) = high.predict(&[0.2]);
        assert!((0.1..0.9).contains(&mu), "smoothed mean {mu}");
        let _ = low; // low-noise fit may need jitter but must not panic
    }

    #[test]
    fn ei_is_nonnegative_and_monotone_in_mean() {
        let x = vec![vec![0.0], vec![1.0]];
        let y = vec![0.0, 1.0];
        let gp = GpPosterior::fit(x, &y, GpParams::default()).unwrap();
        let ei_near_best = gp.expected_improvement(&[1.0], 1.0);
        let ei_near_worst = gp.expected_improvement(&[0.0], 1.0);
        assert!(ei_near_best >= 0.0 && ei_near_worst >= 0.0);
        let ei_mid = gp.expected_improvement(&[0.6], 1.0);
        assert!(ei_mid > ei_near_worst);
    }

    #[test]
    fn normal_cdf_reference_values() {
        assert!((normal_cdf(0.0) - 0.5).abs() < 1e-7);
        assert!((normal_cdf(1.96) - 0.975).abs() < 1e-3);
        assert!((normal_cdf(-1.96) - 0.025).abs() < 1e-3);
    }

    #[test]
    fn prop_posterior_variance_nonnegative_and_ucb_ordered() {
        check("gp posterior sanity", 30, |g| {
            let n = g.usize_range(2, 12);
            let d = g.usize_range(1, 4);
            let x: Vec<Vec<f64>> = (0..n)
                .map(|_| (0..d).map(|_| g.f64_range(0.0, 1.0)).collect())
                .collect();
            let y: Vec<f64> = (0..n).map(|_| g.f64_range(-3.0, 3.0)).collect();
            let gp = GpPosterior::fit(x, &y, GpParams::default().with_noise_hint(true)).unwrap();
            let q: Vec<f64> = (0..d).map(|_| g.f64_range(0.0, 1.0)).collect();
            let (mu, var) = gp.predict(&q);
            assert!(var >= 0.0);
            assert!(mu.is_finite());
            assert!(gp.ucb(&q, 2.0) >= gp.ucb(&q, 0.0) - 1e-12);
        });
    }
}
