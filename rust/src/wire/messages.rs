//! The Vizier message schema (proto-equivalents).
//!
//! These mirror the Vertex/OSS Vizier protocol-buffer definitions the paper
//! describes (§3.1, Appendix D.3): `Study`, `StudySpec`, `ParameterSpec`,
//! `MetricSpec`, `Trial`, `Measurement`, `Metric`, metadata, long-running
//! `Operation`s, and the request/response pairs for every RPC method.
//! Per Table 2 these are the *proto* side; the richer PyVizier-style types
//! live in [`crate::pyvizier`] with converters in
//! [`crate::pyvizier::converters`].

use super::codec::{Reader, WireError, WireMessage, Writer};

// ---------------------------------------------------------------------------
// Enums
// ---------------------------------------------------------------------------

macro_rules! wire_enum {
    ($(#[$doc:meta])* $name:ident { $($variant:ident = $val:expr),+ $(,)? }) => {
        $(#[$doc])*
        #[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
        pub enum $name {
            $($variant = $val),+
        }

        impl $name {
            pub fn from_u64(v: u64) -> Result<Self, WireError> {
                match v {
                    $($val => Ok($name::$variant),)+
                    other => Err(WireError::BadEnum { name: stringify!($name), value: other }),
                }
            }
            pub fn as_u64(self) -> u64 {
                self as u64
            }
        }
    };
}

wire_enum! {
    /// Lifecycle state of a trial (paper §4.1).
    TrialState {
        Requested = 1,
        Active = 2,
        Stopping = 3,
        Completed = 4,
        Infeasible = 5,
    }
}

wire_enum! {
    /// Lifecycle state of a study (paper §4.1).
    StudyState {
        Active = 1,
        Inactive = 2,
        Completed = 3,
    }
}

wire_enum! {
    /// Whether a metric is maximized or minimized.
    MetricGoal {
        Maximize = 1,
        Minimize = 2,
    }
}

wire_enum! {
    /// Scaling type for numerical parameters (paper §4.2).
    ScaleType {
        Linear = 1,
        Log = 2,
        ReverseLog = 3,
    }
}

wire_enum! {
    /// Observation-noise hint (paper Appendix B.2).
    ObservationNoise {
        Unspecified = 0,
        Low = 1,
        High = 2,
    }
}

wire_enum! {
    /// Automated-stopping configuration (paper Appendix B.1).
    StoppingKind {
        None = 0,
        Median = 1,
        DecayCurve = 2,
    }
}

// ---------------------------------------------------------------------------
// Values, parameters, metrics, measurements
// ---------------------------------------------------------------------------

/// A parameter value (the proto uses `google.protobuf.Value`; we use a
/// tagged union with the same reachable states).
#[derive(Debug, Clone, PartialEq)]
pub enum ParamValue {
    F64(f64),
    I64(i64),
    Str(String),
    Bool(bool),
}

impl WireMessage for ParamValue {
    fn encode_fields(&self, w: &mut Writer) {
        match self {
            ParamValue::F64(v) => w.f64(1, *v),
            ParamValue::I64(v) => w.i64(2, *v),
            ParamValue::Str(v) => w.str(3, v),
            ParamValue::Bool(v) => w.bool(4, *v),
        }
    }
    fn decode_fields(r: &mut Reader) -> Result<Self, WireError> {
        let mut out = None;
        while let Some((f, v)) = r.next_field()? {
            out = Some(match f {
                1 => ParamValue::F64(v.as_f64()?),
                2 => ParamValue::I64(v.as_i64()?),
                3 => ParamValue::Str(v.as_string()?),
                4 => ParamValue::Bool(v.as_bool()?),
                _ => continue,
            });
        }
        out.ok_or(WireError::MissingField("ParamValue.oneof"))
    }
}

/// One named parameter inside a trial.
#[derive(Debug, Clone, PartialEq)]
pub struct TrialParameter {
    pub parameter_id: String,
    pub value: ParamValue,
}

impl WireMessage for TrialParameter {
    fn encode_fields(&self, w: &mut Writer) {
        w.str(1, &self.parameter_id);
        w.msg(2, &self.value);
    }
    fn decode_fields(r: &mut Reader) -> Result<Self, WireError> {
        let mut id = None;
        let mut value = None;
        while let Some((f, v)) = r.next_field()? {
            match f {
                1 => id = Some(v.as_string()?),
                2 => value = Some(v.as_msg()?),
                _ => {}
            }
        }
        Ok(Self {
            parameter_id: id.ok_or(WireError::MissingField("TrialParameter.parameter_id"))?,
            value: value.ok_or(WireError::MissingField("TrialParameter.value"))?,
        })
    }
}

/// One named metric value.
#[derive(Debug, Clone, PartialEq)]
pub struct Metric {
    pub metric_id: String,
    pub value: f64,
}

impl WireMessage for Metric {
    fn encode_fields(&self, w: &mut Writer) {
        w.str(1, &self.metric_id);
        w.f64(2, self.value);
    }
    fn decode_fields(r: &mut Reader) -> Result<Self, WireError> {
        let mut id = None;
        let mut value = 0.0;
        while let Some((f, v)) = r.next_field()? {
            match f {
                1 => id = Some(v.as_string()?),
                2 => value = v.as_f64()?,
                _ => {}
            }
        }
        Ok(Self {
            metric_id: id.ok_or(WireError::MissingField("Metric.metric_id"))?,
            value,
        })
    }
}

/// An (intermediate or final) evaluation of a trial.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Measurement {
    pub step_count: i64,
    pub elapsed_secs: f64,
    pub metrics: Vec<Metric>,
}

impl WireMessage for Measurement {
    fn encode_fields(&self, w: &mut Writer) {
        w.i64(1, self.step_count);
        w.f64(2, self.elapsed_secs);
        w.msgs(3, &self.metrics);
    }
    fn decode_fields(r: &mut Reader) -> Result<Self, WireError> {
        let mut m = Measurement::default();
        while let Some((f, v)) = r.next_field()? {
            match f {
                1 => m.step_count = v.as_i64()?,
                2 => m.elapsed_secs = v.as_f64()?,
                3 => m.metrics.push(v.as_msg()?),
                _ => {}
            }
        }
        Ok(m)
    }
}

/// One namespaced key-value metadata entry (paper §4.1, §6.3).
#[derive(Debug, Clone, PartialEq)]
pub struct MetadataItem {
    pub namespace: String,
    pub key: String,
    pub value: Vec<u8>,
}

impl WireMessage for MetadataItem {
    fn encode_fields(&self, w: &mut Writer) {
        w.str(1, &self.namespace);
        w.str(2, &self.key);
        w.bytes(3, &self.value);
    }
    fn decode_fields(r: &mut Reader) -> Result<Self, WireError> {
        let (mut ns, mut key, mut value) = (String::new(), None, Vec::new());
        while let Some((f, v)) = r.next_field()? {
            match f {
                1 => ns = v.as_string()?,
                2 => key = Some(v.as_string()?),
                3 => value = v.as_bytes()?.to_vec(),
                _ => {}
            }
        }
        Ok(Self {
            namespace: ns,
            key: key.ok_or(WireError::MissingField("MetadataItem.key"))?,
            value,
        })
    }
}

// ---------------------------------------------------------------------------
// Trial
// ---------------------------------------------------------------------------

/// A suggestion plus (eventually) its evaluation (paper §4.1: "a Trial
/// without f(x) is also considered a suggestion").
#[derive(Debug, Clone, PartialEq)]
pub struct TrialProto {
    pub id: u64,
    pub state: TrialState,
    pub parameters: Vec<TrialParameter>,
    pub final_measurement: Option<Measurement>,
    pub measurements: Vec<Measurement>,
    pub client_id: String,
    pub infeasibility_reason: String,
    pub metadata: Vec<MetadataItem>,
    pub created_ms: u64,
    pub completed_ms: u64,
}

impl Default for TrialProto {
    fn default() -> Self {
        Self {
            id: 0,
            state: TrialState::Requested,
            parameters: Vec::new(),
            final_measurement: None,
            measurements: Vec::new(),
            client_id: String::new(),
            infeasibility_reason: String::new(),
            metadata: Vec::new(),
            created_ms: 0,
            completed_ms: 0,
        }
    }
}

impl WireMessage for TrialProto {
    fn encode_fields(&self, w: &mut Writer) {
        w.u64(1, self.id);
        w.u64(2, self.state.as_u64());
        w.msgs(3, &self.parameters);
        if let Some(fm) = &self.final_measurement {
            w.msg(4, fm);
        }
        w.msgs(5, &self.measurements);
        w.str(6, &self.client_id);
        w.str(7, &self.infeasibility_reason);
        w.msgs(8, &self.metadata);
        w.u64(9, self.created_ms);
        w.u64(10, self.completed_ms);
    }
    fn decode_fields(r: &mut Reader) -> Result<Self, WireError> {
        let mut t = TrialProto::default();
        while let Some((f, v)) = r.next_field()? {
            match f {
                1 => t.id = v.as_u64()?,
                2 => t.state = TrialState::from_u64(v.as_u64()?)?,
                3 => t.parameters.push(v.as_msg()?),
                4 => t.final_measurement = Some(v.as_msg()?),
                5 => t.measurements.push(v.as_msg()?),
                6 => t.client_id = v.as_string()?,
                7 => t.infeasibility_reason = v.as_string()?,
                8 => t.metadata.push(v.as_msg()?),
                9 => t.created_ms = v.as_u64()?,
                10 => t.completed_ms = v.as_u64()?,
                _ => {}
            }
        }
        Ok(t)
    }
}

// ---------------------------------------------------------------------------
// ParameterSpec (recursive: conditional children, paper §4.2)
// ---------------------------------------------------------------------------

/// The kind-specific payload of a parameter spec.
#[derive(Debug, Clone, PartialEq)]
pub enum ParameterKind {
    /// Continuous range `[min, max]`.
    Double { min: f64, max: f64 },
    /// Integer range `[min, max]`.
    Integer { min: i64, max: i64 },
    /// Finite ordered set of real values.
    Discrete { values: Vec<f64> },
    /// Unordered list of strings.
    Categorical { values: Vec<String> },
}

/// Values of the parent parameter under which a child spec is active.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ParentValues {
    pub values: Vec<ParamValue>,
}

impl WireMessage for ParentValues {
    fn encode_fields(&self, w: &mut Writer) {
        w.msgs(1, &self.values);
    }
    fn decode_fields(r: &mut Reader) -> Result<Self, WireError> {
        let mut p = ParentValues::default();
        while let Some((f, v)) = r.next_field()? {
            if f == 1 {
                p.values.push(v.as_msg()?);
            }
        }
        Ok(p)
    }
}

/// A child spec active only for certain parent values.
#[derive(Debug, Clone, PartialEq)]
pub struct ConditionalParameterSpec {
    pub parent_values: ParentValues,
    pub spec: ParameterSpecProto,
}

impl WireMessage for ConditionalParameterSpec {
    fn encode_fields(&self, w: &mut Writer) {
        w.msg(1, &self.parent_values);
        w.msg(2, &self.spec);
    }
    fn decode_fields(r: &mut Reader) -> Result<Self, WireError> {
        let mut pv = None;
        let mut spec = None;
        while let Some((f, v)) = r.next_field()? {
            match f {
                1 => pv = Some(v.as_msg()?),
                2 => spec = Some(v.as_msg()?),
                _ => {}
            }
        }
        Ok(Self {
            parent_values: pv.ok_or(WireError::MissingField("ConditionalParameterSpec.parent_values"))?,
            spec: spec.ok_or(WireError::MissingField("ConditionalParameterSpec.spec"))?,
        })
    }
}

/// A parameter specification (proto side of Table 2's `ParameterSpec`).
#[derive(Debug, Clone, PartialEq)]
pub struct ParameterSpecProto {
    pub parameter_id: String,
    pub kind: ParameterKind,
    pub scale_type: ScaleType,
    pub conditional_children: Vec<ConditionalParameterSpec>,
}

impl WireMessage for ParameterSpecProto {
    fn encode_fields(&self, w: &mut Writer) {
        w.str(1, &self.parameter_id);
        w.u64(2, self.scale_type.as_u64());
        match &self.kind {
            ParameterKind::Double { min, max } => {
                let mut inner = Writer::new();
                inner.f64(1, *min);
                inner.f64(2, *max);
                w.bytes(3, &inner.into_bytes());
            }
            ParameterKind::Integer { min, max } => {
                let mut inner = Writer::new();
                inner.i64(1, *min);
                inner.i64(2, *max);
                w.bytes(4, &inner.into_bytes());
            }
            ParameterKind::Discrete { values } => {
                let mut inner = Writer::new();
                inner.f64s_packed(1, values);
                w.bytes(5, &inner.into_bytes());
            }
            ParameterKind::Categorical { values } => {
                let mut inner = Writer::new();
                for value in values {
                    inner.str(1, value);
                }
                w.bytes(6, &inner.into_bytes());
            }
        }
        w.msgs(7, &self.conditional_children);
    }
    fn decode_fields(r: &mut Reader) -> Result<Self, WireError> {
        let mut id = None;
        let mut scale = ScaleType::Linear;
        let mut kind = None;
        let mut children = Vec::new();
        while let Some((f, v)) = r.next_field()? {
            match f {
                1 => id = Some(v.as_string()?),
                2 => scale = ScaleType::from_u64(v.as_u64()?)?,
                3 => {
                    let mut inner = Reader::new(v.as_bytes()?);
                    let (mut min, mut max) = (0.0, 0.0);
                    while let Some((g, u)) = inner.next_field()? {
                        match g {
                            1 => min = u.as_f64()?,
                            2 => max = u.as_f64()?,
                            _ => {}
                        }
                    }
                    kind = Some(ParameterKind::Double { min, max });
                }
                4 => {
                    let mut inner = Reader::new(v.as_bytes()?);
                    let (mut min, mut max) = (0i64, 0i64);
                    while let Some((g, u)) = inner.next_field()? {
                        match g {
                            1 => min = u.as_i64()?,
                            2 => max = u.as_i64()?,
                            _ => {}
                        }
                    }
                    kind = Some(ParameterKind::Integer { min, max });
                }
                5 => {
                    let mut inner = Reader::new(v.as_bytes()?);
                    let mut values = Vec::new();
                    while let Some((g, u)) = inner.next_field()? {
                        if g == 1 {
                            values = u.as_f64s_packed()?;
                        }
                    }
                    kind = Some(ParameterKind::Discrete { values });
                }
                6 => {
                    let mut inner = Reader::new(v.as_bytes()?);
                    let mut values = Vec::new();
                    while let Some((g, u)) = inner.next_field()? {
                        if g == 1 {
                            values.push(u.as_string()?);
                        }
                    }
                    kind = Some(ParameterKind::Categorical { values });
                }
                7 => children.push(v.as_msg()?),
                _ => {}
            }
        }
        Ok(Self {
            parameter_id: id.ok_or(WireError::MissingField("ParameterSpec.parameter_id"))?,
            kind: kind.ok_or(WireError::MissingField("ParameterSpec.kind"))?,
            scale_type: scale,
            conditional_children: children,
        })
    }
}

// ---------------------------------------------------------------------------
// MetricSpec, stopping config, StudySpec, Study
// ---------------------------------------------------------------------------

/// Specification of one objective metric.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricSpecProto {
    pub metric_id: String,
    pub goal: MetricGoal,
    /// Optional range hints (Code Block 1 passes min/max for accuracy).
    pub min_value: f64,
    pub max_value: f64,
}

impl WireMessage for MetricSpecProto {
    fn encode_fields(&self, w: &mut Writer) {
        w.str(1, &self.metric_id);
        w.u64(2, self.goal.as_u64());
        w.f64(3, self.min_value);
        w.f64(4, self.max_value);
    }
    fn decode_fields(r: &mut Reader) -> Result<Self, WireError> {
        let mut id = None;
        let mut goal = MetricGoal::Maximize;
        let (mut min_value, mut max_value) = (f64::NEG_INFINITY, f64::INFINITY);
        while let Some((f, v)) = r.next_field()? {
            match f {
                1 => id = Some(v.as_string()?),
                2 => goal = MetricGoal::from_u64(v.as_u64()?)?,
                3 => min_value = v.as_f64()?,
                4 => max_value = v.as_f64()?,
                _ => {}
            }
        }
        Ok(Self {
            metric_id: id.ok_or(WireError::MissingField("MetricSpec.metric_id"))?,
            goal,
            min_value,
            max_value,
        })
    }
}

/// Automated-stopping configuration (Appendix B.1).
#[derive(Debug, Clone, PartialEq)]
pub struct StoppingConfig {
    pub kind: StoppingKind,
    /// Median: minimum number of completed trials before stopping engages.
    pub min_trials: u64,
    /// DecayCurve: UCB multiplier for the predicted-final-value test.
    pub confidence: f64,
}

impl Default for StoppingConfig {
    fn default() -> Self {
        Self {
            kind: StoppingKind::None,
            min_trials: 5,
            confidence: 1.64,
        }
    }
}

impl WireMessage for StoppingConfig {
    fn encode_fields(&self, w: &mut Writer) {
        w.u64(1, self.kind.as_u64());
        w.u64(2, self.min_trials);
        w.f64(3, self.confidence);
    }
    fn decode_fields(r: &mut Reader) -> Result<Self, WireError> {
        let mut s = StoppingConfig::default();
        while let Some((f, v)) = r.next_field()? {
            match f {
                1 => s.kind = StoppingKind::from_u64(v.as_u64()?)?,
                2 => s.min_trials = v.as_u64()?,
                3 => s.confidence = v.as_f64()?,
                _ => {}
            }
        }
        Ok(s)
    }
}

/// The study configuration (proto side of Table 2's `StudySpec`).
#[derive(Debug, Clone, PartialEq)]
pub struct StudySpecProto {
    pub parameters: Vec<ParameterSpecProto>,
    pub metrics: Vec<MetricSpecProto>,
    pub algorithm: String,
    pub observation_noise: ObservationNoise,
    pub stopping: StoppingConfig,
    pub metadata: Vec<MetadataItem>,
    /// Seed for deterministic policies (0 = unseeded).
    pub seed: u64,
}

impl Default for StudySpecProto {
    fn default() -> Self {
        Self {
            parameters: Vec::new(),
            metrics: Vec::new(),
            algorithm: String::new(),
            observation_noise: ObservationNoise::Unspecified,
            stopping: StoppingConfig::default(),
            metadata: Vec::new(),
            seed: 0,
        }
    }
}

impl WireMessage for StudySpecProto {
    fn encode_fields(&self, w: &mut Writer) {
        w.msgs(1, &self.parameters);
        w.msgs(2, &self.metrics);
        w.str(3, &self.algorithm);
        w.u64(4, self.observation_noise.as_u64());
        w.msg(5, &self.stopping);
        w.msgs(6, &self.metadata);
        w.u64(7, self.seed);
    }
    fn decode_fields(r: &mut Reader) -> Result<Self, WireError> {
        let mut s = StudySpecProto::default();
        while let Some((f, v)) = r.next_field()? {
            match f {
                1 => s.parameters.push(v.as_msg()?),
                2 => s.metrics.push(v.as_msg()?),
                3 => s.algorithm = v.as_string()?,
                4 => s.observation_noise = ObservationNoise::from_u64(v.as_u64()?)?,
                5 => s.stopping = v.as_msg()?,
                6 => s.metadata.push(v.as_msg()?),
                7 => s.seed = v.as_u64()?,
                _ => {}
            }
        }
        Ok(s)
    }
}

/// A study resource.
#[derive(Debug, Clone, PartialEq)]
pub struct StudyProto {
    pub name: String,
    pub display_name: String,
    pub state: StudyState,
    pub spec: StudySpecProto,
    pub created_ms: u64,
}

impl Default for StudyProto {
    fn default() -> Self {
        Self {
            name: String::new(),
            display_name: String::new(),
            state: StudyState::Active,
            spec: StudySpecProto::default(),
            created_ms: 0,
        }
    }
}

impl WireMessage for StudyProto {
    fn encode_fields(&self, w: &mut Writer) {
        w.str(1, &self.name);
        w.str(2, &self.display_name);
        w.u64(3, self.state.as_u64());
        w.msg(4, &self.spec);
        w.u64(5, self.created_ms);
    }
    fn decode_fields(r: &mut Reader) -> Result<Self, WireError> {
        let mut s = StudyProto::default();
        while let Some((f, v)) = r.next_field()? {
            match f {
                1 => s.name = v.as_string()?,
                2 => s.display_name = v.as_string()?,
                3 => s.state = StudyState::from_u64(v.as_u64()?)?,
                4 => s.spec = v.as_msg()?,
                5 => s.created_ms = v.as_u64()?,
                _ => {}
            }
        }
        Ok(s)
    }
}

// ---------------------------------------------------------------------------
// Operations (paper §3.2: durable long-running computations)
// ---------------------------------------------------------------------------

wire_enum! {
    /// What computation an operation tracks.
    OperationKind {
        SuggestTrials = 1,
        EarlyStopping = 2,
    }
}

/// One trial's early-stopping verdict (Pythia v2: early-stopping
/// operations carry a decision per requested trial).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct TrialStopDecision {
    pub trial_id: u64,
    pub should_stop: bool,
    pub reason: String,
}

impl WireMessage for TrialStopDecision {
    fn encode_fields(&self, w: &mut Writer) {
        w.u64(1, self.trial_id);
        w.bool(2, self.should_stop);
        w.str(3, &self.reason);
    }
    fn decode_fields(r: &mut Reader) -> Result<Self, WireError> {
        let mut d = TrialStopDecision::default();
        while let Some((f, v)) = r.next_field()? {
            match f {
                1 => d.trial_id = v.as_u64()?,
                2 => d.should_stop = v.as_bool()?,
                3 => d.reason = v.as_string()?,
                _ => {}
            }
        }
        Ok(d)
    }
}

/// A durable long-running operation. Stored in the datastore so the server
/// can resume/restart the computation after a crash (paper §3.2,
/// "Server-side Fault Tolerance").
#[derive(Debug, Clone, PartialEq)]
pub struct OperationProto {
    pub name: String,
    pub kind: OperationKind,
    pub study_name: String,
    pub client_id: String,
    pub done: bool,
    pub error: String,
    /// SuggestTrials result: the suggested trials.
    pub trials: Vec<TrialProto>,
    /// SuggestTrials input: how many suggestions were requested.
    pub count: u64,
    /// EarlyStopping input: the trials to judge (empty = every trial that
    /// was ACTIVE when the operation ran). A v1 single-trial encoding
    /// decodes as a one-element list (same field number).
    pub trial_ids: Vec<u64>,
    /// EarlyStopping result: one verdict per judged trial.
    pub stop_decisions: Vec<TrialStopDecision>,
    pub created_ms: u64,
}

impl Default for OperationProto {
    fn default() -> Self {
        Self {
            name: String::new(),
            kind: OperationKind::SuggestTrials,
            study_name: String::new(),
            client_id: String::new(),
            done: false,
            error: String::new(),
            trials: Vec::new(),
            count: 0,
            trial_ids: Vec::new(),
            stop_decisions: Vec::new(),
            created_ms: 0,
        }
    }
}

impl WireMessage for OperationProto {
    fn encode_fields(&self, w: &mut Writer) {
        w.str(1, &self.name);
        w.u64(2, self.kind.as_u64());
        w.str(3, &self.study_name);
        w.str(4, &self.client_id);
        w.bool(5, self.done);
        w.str(6, &self.error);
        w.msgs(7, &self.trials);
        w.u64(8, self.count);
        for id in &self.trial_ids {
            w.u64(9, *id);
        }
        w.u64(11, self.created_ms);
        w.msgs(12, &self.stop_decisions);
    }
    fn decode_fields(r: &mut Reader) -> Result<Self, WireError> {
        let mut o = OperationProto::default();
        let mut legacy_should_stop = false;
        while let Some((f, v)) = r.next_field()? {
            match f {
                1 => o.name = v.as_string()?,
                2 => o.kind = OperationKind::from_u64(v.as_u64()?)?,
                3 => o.study_name = v.as_string()?,
                4 => o.client_id = v.as_string()?,
                5 => o.done = v.as_bool()?,
                6 => o.error = v.as_string()?,
                7 => o.trials.push(v.as_msg()?),
                8 => o.count = v.as_u64()?,
                9 => o.trial_ids.push(v.as_u64()?),
                10 => legacy_should_stop = v.as_bool()?, // v1 single-trial verdict
                11 => o.created_ms = v.as_u64()?,
                12 => o.stop_decisions.push(v.as_msg()?),
                _ => {}
            }
        }
        // A v1 record (e.g. replayed from an old WAL) carried its verdict
        // as field 10 + the single trial id in field 9; don't drop an
        // acknowledged stop decision on upgrade.
        if legacy_should_stop && o.stop_decisions.is_empty() {
            if let Some(&trial_id) = o.trial_ids.first() {
                o.stop_decisions.push(TrialStopDecision {
                    trial_id,
                    should_stop: true,
                    reason: String::new(),
                });
            }
        }
        Ok(o)
    }
}

// ---------------------------------------------------------------------------
// RPC request/response messages
// ---------------------------------------------------------------------------

macro_rules! simple_msg {
    ($(#[$doc:meta])* $name:ident { $($fnum:expr => $field:ident : $ty:tt),* $(,)? }) => {
        $(#[$doc])*
        #[derive(Debug, Clone, PartialEq, Default)]
        pub struct $name {
            $(pub $field: simple_msg!(@ty $ty),)*
        }

        impl WireMessage for $name {
            fn encode_fields(&self, #[allow(unused)] w: &mut Writer) {
                $(simple_msg!(@enc self, w, $fnum, $field, $ty);)*
            }
            fn decode_fields(r: &mut Reader) -> Result<Self, WireError> {
                #[allow(unused_mut)]
                let mut m = $name::default();
                while let Some((f, v)) = r.next_field()? {
                    let _ = &v;
                    match f {
                        $($fnum => simple_msg!(@dec m, v, $field, $ty),)*
                        _ => {}
                    }
                }
                Ok(m)
            }
        }
    };
    (@ty str) => { String };
    (@ty u64) => { u64 };
    (@ty bool) => { bool };
    (@ty (msg $t:ty)) => { $t };
    (@ty (optmsg $t:ty)) => { Option<$t> };
    (@ty (repmsg $t:ty)) => { Vec<$t> };
    (@enc $s:ident, $w:ident, $f:expr, $field:ident, str) => { $w.str($f, &$s.$field); };
    (@enc $s:ident, $w:ident, $f:expr, $field:ident, u64) => { $w.u64($f, $s.$field); };
    (@enc $s:ident, $w:ident, $f:expr, $field:ident, bool) => { $w.bool($f, $s.$field); };
    (@enc $s:ident, $w:ident, $f:expr, $field:ident, (msg $t:ty)) => { $w.msg($f, &$s.$field); };
    (@enc $s:ident, $w:ident, $f:expr, $field:ident, (optmsg $t:ty)) => {
        if let Some(m) = &$s.$field { $w.msg($f, m); }
    };
    (@enc $s:ident, $w:ident, $f:expr, $field:ident, (repmsg $t:ty)) => { $w.msgs($f, &$s.$field); };
    (@dec $m:ident, $v:ident, $field:ident, str) => { $m.$field = $v.as_string()? };
    (@dec $m:ident, $v:ident, $field:ident, u64) => { $m.$field = $v.as_u64()? };
    (@dec $m:ident, $v:ident, $field:ident, bool) => { $m.$field = $v.as_bool()? };
    (@dec $m:ident, $v:ident, $field:ident, (msg $t:ty)) => { $m.$field = $v.as_msg()? };
    (@dec $m:ident, $v:ident, $field:ident, (optmsg $t:ty)) => { $m.$field = Some($v.as_msg()?) };
    (@dec $m:ident, $v:ident, $field:ident, (repmsg $t:ty)) => { $m.$field.push($v.as_msg()?) };
}

simple_msg! {
    /// CreateStudy: registers a study; returns the stored resource.
    CreateStudyRequest { 1 => study: (msg StudyProto) }
}
simple_msg! { StudyResponse { 1 => study: (msg StudyProto) } }
simple_msg! { GetStudyRequest { 1 => name: str } }
simple_msg! { LookupStudyRequest { 1 => display_name: str } }
simple_msg! { DeleteStudyRequest { 1 => name: str } }
simple_msg! {
    /// ListStudies with optional pagination: `page_size == 0` returns
    /// everything (v1 behaviour); otherwise at most `page_size` studies
    /// starting after `page_token` (opaque, from the previous response).
    ListStudiesRequest { 1 => page_size: u64, 2 => page_token: str }
}
simple_msg! {
    /// `next_page_token` is empty when the listing is exhausted.
    ListStudiesResponse {
        1 => studies: (repmsg StudyProto),
        2 => next_page_token: str,
    }
}
simple_msg! { EmptyResponse {} }

simple_msg! {
    /// SuggestTrials: asks the Pythia policy for `count` suggestions on
    /// behalf of `client_id`. Returns a long-running operation.
    SuggestTrialsRequest {
        1 => study_name: str,
        2 => count: u64,
        3 => client_id: str,
    }
}
simple_msg! { OperationResponse { 1 => operation: (msg OperationProto) } }
simple_msg! { GetOperationRequest { 1 => name: str } }
simple_msg! {
    /// WaitOperation: long-poll `name` server-side. Returns when the
    /// operation completes or after ~`timeout_ms` (0 = server default),
    /// whichever is first — the response carries the operation's state
    /// either way, so a timeout is *not* an error (mirrors
    /// `google.longrunning.WaitOperation`). Servers cap the timeout;
    /// clients chunk longer waits into successive calls.
    WaitOperationRequest { 1 => name: str, 2 => timeout_ms: u64 }
}
simple_msg! {
    /// GetServiceMetrics: snapshot of the service + front-end counters.
    GetServiceMetricsRequest {}
}

simple_msg! {
    /// One named scalar metric (a monotonic counter or a point-in-time
    /// gauge — the `kind` is implied by which repeated field carries it).
    MetricPointProto { 1 => name: str, 2 => value: u64 }
}

/// One named latency histogram: summary stats plus the raw log-bucket
/// counts, so clients can render the same table the server used to format.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct MetricHistogramProto {
    pub name: String,
    pub count: u64,
    /// Sum of recorded values in µs (not the mean: the sum recomputes
    /// the exact float mean client-side, `sum_us / count`).
    pub sum_us: u64,
    pub p50_us: u64,
    pub p99_us: u64,
    /// Log2 bucket counts (bucket i covers `[2^i, 2^(i+1))` µs).
    pub buckets: Vec<u64>,
}

impl MetricHistogramProto {
    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }
}

impl WireMessage for MetricHistogramProto {
    fn encode_fields(&self, w: &mut Writer) {
        w.str(1, &self.name);
        w.u64(2, self.count);
        w.u64(3, self.sum_us);
        w.u64(4, self.p50_us);
        w.u64(5, self.p99_us);
        for b in &self.buckets {
            w.u64(6, *b);
        }
    }
    fn decode_fields(r: &mut Reader) -> Result<Self, WireError> {
        let mut m = MetricHistogramProto::default();
        while let Some((f, v)) = r.next_field()? {
            match f {
                1 => m.name = v.as_string()?,
                2 => m.count = v.as_u64()?,
                3 => m.sum_us = v.as_u64()?,
                4 => m.p50_us = v.as_u64()?,
                5 => m.p99_us = v.as_u64()?,
                6 => m.buckets.push(v.as_u64()?),
                _ => {}
            }
        }
        Ok(m)
    }
}

simple_msg! {
    /// Counter snapshot (Pythia v2 follow-up (c)). Fields 1–10 are the
    /// original flat counters (kept for old clients). Fields 12–14 are the
    /// typed snapshot — every counter, gauge, and latency histogram the
    /// server tracks, by name — from which new clients render the text
    /// report *client-side* ([`crate::client::VizierClient::service_metrics`]);
    /// field 11 (`report`) is the retired server-rendered text, still
    /// decoded so old servers keep working.
    ServiceMetricsResponse {
        1 => policy_runs: u64,
        2 => suggest_ops_served: u64,
        3 => in_flight_policy_jobs: u64,
        4 => errors: u64,
        5 => wait_wakeups: u64,
        6 => wait_wakeup_mean_us: u64,
        7 => active_connections: u64,
        8 => parked_responses: u64,
        9 => connections_total: u64,
        10 => requests: u64,
        11 => report: str,
        12 => counters: (repmsg MetricPointProto),
        13 => gauges: (repmsg MetricPointProto),
        14 => histograms: (repmsg MetricHistogramProto),
    }
}

simple_msg! {
    /// v2 `HELLO` handshake body (both directions). The client proposes
    /// its highest supported `version`; the server echoes the highest
    /// mutually supported one plus the per-connection in-flight request
    /// cap it will enforce (`max_inflight`, 0 = server default).
    HelloProto { 1 => version: u64, 2 => max_inflight: u64 }
}

simple_msg! {
    AddMeasurementRequest {
        1 => study_name: str,
        2 => trial_id: u64,
        3 => measurement: (msg Measurement),
    }
}
simple_msg! {
    CompleteTrialRequest {
        1 => study_name: str,
        2 => trial_id: u64,
        3 => final_measurement: (optmsg Measurement),
        4 => infeasible: bool,
        5 => infeasibility_reason: str,
    }
}
simple_msg! { TrialResponse { 1 => trial: (msg TrialProto) } }
simple_msg! {
    /// ListTrials with optional pagination (mirrors `ListStudies`):
    /// `page_size == 0` with an empty token returns every trial (v1
    /// behaviour); otherwise at most `page_size` trials after the
    /// position encoded by `page_token` (opaque, from the previous
    /// response). Large studies no longer have to ship every trial in
    /// one response frame.
    ListTrialsRequest { 1 => study_name: str, 2 => page_size: u64, 3 => page_token: str }
}
simple_msg! {
    /// `next_page_token` is empty when the listing is exhausted.
    ListTrialsResponse {
        1 => trials: (repmsg TrialProto),
        2 => next_page_token: str,
    }
}
simple_msg! { GetTrialRequest { 1 => study_name: str, 2 => trial_id: u64 } }
simple_msg! { DeleteTrialRequest { 1 => study_name: str, 2 => trial_id: u64 } }

/// CheckEarlyStopping, batched (Pythia v2): ask about many trials in one
/// operation. `trial_ids` empty = "every ACTIVE trial". A v1 single-trial
/// request decodes as a one-element list (same field number).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct CheckEarlyStoppingRequest {
    pub study_name: String,
    pub trial_ids: Vec<u64>,
}

impl WireMessage for CheckEarlyStoppingRequest {
    fn encode_fields(&self, w: &mut Writer) {
        w.str(1, &self.study_name);
        for id in &self.trial_ids {
            w.u64(2, *id);
        }
    }
    fn decode_fields(r: &mut Reader) -> Result<Self, WireError> {
        let mut m = CheckEarlyStoppingRequest::default();
        while let Some((f, v)) = r.next_field()? {
            match f {
                1 => m.study_name = v.as_string()?,
                2 => m.trial_ids.push(v.as_u64()?),
                _ => {}
            }
        }
        Ok(m)
    }
}
simple_msg! { StopTrialRequest { 1 => study_name: str, 2 => trial_id: u64 } }
simple_msg! { ListOptimalTrialsRequest { 1 => study_name: str } }

/// One metadata write: `trial_id == 0` targets the StudySpec table, any
/// other value targets that trial (the two metadata tables of §6.3).
///
/// Pythia v2 follow-up (b): when `new_trial_index > 0` the update targets
/// the `(new_trial_index - 1)`-th trial *being suggested in the same
/// decision* — the policy has no real ids yet, so it addresses its own
/// batch positionally and the service resolves the placeholder to the
/// registered trial id atomically with the batch
/// (`trial_id` must be 0 in that case).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct UnitMetadataUpdate {
    pub trial_id: u64,
    pub item: Option<MetadataItem>,
    /// 1-based index into the decision's suggestion batch; 0 = unset.
    pub new_trial_index: u64,
}

impl WireMessage for UnitMetadataUpdate {
    fn encode_fields(&self, w: &mut Writer) {
        w.u64(1, self.trial_id);
        if let Some(item) = &self.item {
            w.msg(2, item);
        }
        if self.new_trial_index > 0 {
            w.u64(3, self.new_trial_index);
        }
    }
    fn decode_fields(r: &mut Reader) -> Result<Self, WireError> {
        let mut u = UnitMetadataUpdate::default();
        while let Some((f, v)) = r.next_field()? {
            match f {
                1 => u.trial_id = v.as_u64()?,
                2 => u.item = Some(v.as_msg()?),
                3 => u.new_trial_index = v.as_u64()?,
                _ => {}
            }
        }
        Ok(u)
    }
}

simple_msg! {
    UpdateMetadataRequest {
        1 => study_name: str,
        2 => updates: (repmsg UnitMetadataUpdate),
    }
}

// ---------------------------------------------------------------------------
// Tracing: context trailer + GetTraces messages
// ---------------------------------------------------------------------------

simple_msg! {
    /// Trace context carried across process boundaries (v2 request
    /// frames, Pythia hops) as an optional *trailer*: see
    /// [`append_trace_context`].
    TraceContextProto { 1 => trace_id: u64, 2 => span_id: u64 }
}

/// Field number of the trace-context trailer. Every request message in
/// this schema uses small field numbers and every decoder skips unknown
/// fields, so appending this high-numbered field after the encoded
/// request bytes is invisible to peers that don't look for it — v1
/// stays byte-identical because only the v2/Pythia clients append it
/// (spec: `docs/WIRE.md` §trace-context trailer).
pub const TRACE_CONTEXT_FIELD: u32 = 2047;

/// Append `ctx` to an already-encoded request payload as a trailer
/// field. Decoding the payload as its request type still works (unknown
/// fields are skipped); [`extract_trace_context`] recovers the context.
pub fn append_trace_context(payload: &mut Vec<u8>, ctx: crate::util::trace::TraceCtx) {
    let mut w = Writer::new();
    w.msg(
        TRACE_CONTEXT_FIELD,
        &TraceContextProto { trace_id: ctx.trace_id, span_id: ctx.span_id },
    );
    payload.extend_from_slice(&w.into_bytes());
}

/// Scan a request payload for a trace-context trailer. Returns `None`
/// for payloads without one (every v1 client) or with a zero trace id;
/// malformed payloads also yield `None` — the request decoder will
/// report the real error.
pub fn extract_trace_context(payload: &[u8]) -> Option<crate::util::trace::TraceCtx> {
    let mut r = Reader::new(payload);
    let mut found = None;
    while let Ok(Some((f, v))) = r.next_field() {
        if f == TRACE_CONTEXT_FIELD {
            if let Ok(p) = v.as_msg::<TraceContextProto>() {
                if p.trace_id != 0 {
                    found =
                        Some(crate::util::trace::TraceCtx { trace_id: p.trace_id, span_id: p.span_id });
                }
            }
        }
    }
    found
}

simple_msg! {
    /// GetTraces: fetch the `limit` slowest recent traces (default 10).
    /// `include_infra` adds the pseudo-trace of background spans (fsync
    /// batches, segment rotations) as trace id 0.
    GetTracesRequest { 1 => limit: u64, 2 => include_infra: bool }
}
simple_msg! {
    /// One span of a trace. `parent_id == 0` means a root; a nonzero
    /// parent absent from the same trace belongs to a remote process
    /// (the client side of the wire).
    SpanProto {
        1 => span_id: u64,
        2 => parent_id: u64,
        3 => name: str,
        4 => start_us: u64,
        5 => duration_us: u64,
    }
}
simple_msg! {
    /// One trace: its spans plus the precomputed wall duration
    /// (max end − min start over the spans the server still had).
    TraceProto {
        1 => trace_id: u64,
        2 => duration_us: u64,
        3 => spans: (repmsg SpanProto),
    }
}
simple_msg! { GetTracesResponse { 1 => traces: (repmsg TraceProto) } }

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wire::codec::{decode, encode};

    fn sample_spec() -> StudySpecProto {
        StudySpecProto {
            parameters: vec![
                ParameterSpecProto {
                    parameter_id: "learning_rate".into(),
                    kind: ParameterKind::Double { min: 1e-4, max: 1e-2 },
                    scale_type: ScaleType::Log,
                    conditional_children: vec![],
                },
                ParameterSpecProto {
                    parameter_id: "model".into(),
                    kind: ParameterKind::Categorical {
                        values: vec!["linear".into(), "dnn".into()],
                    },
                    scale_type: ScaleType::Linear,
                    conditional_children: vec![ConditionalParameterSpec {
                        parent_values: ParentValues {
                            values: vec![ParamValue::Str("dnn".into())],
                        },
                        spec: ParameterSpecProto {
                            parameter_id: "num_layers".into(),
                            kind: ParameterKind::Integer { min: 1, max: 5 },
                            scale_type: ScaleType::Linear,
                            conditional_children: vec![],
                        },
                    }],
                },
                ParameterSpecProto {
                    parameter_id: "batch".into(),
                    kind: ParameterKind::Discrete {
                        values: vec![16.0, 32.0, 64.0],
                    },
                    scale_type: ScaleType::Linear,
                    conditional_children: vec![],
                },
            ],
            metrics: vec![MetricSpecProto {
                metric_id: "accuracy".into(),
                goal: MetricGoal::Maximize,
                min_value: 0.0,
                max_value: 1.0,
            }],
            algorithm: "RANDOM_SEARCH".into(),
            observation_noise: ObservationNoise::High,
            stopping: StoppingConfig {
                kind: StoppingKind::Median,
                min_trials: 3,
                confidence: 1.0,
            },
            metadata: vec![MetadataItem {
                namespace: "algo".into(),
                key: "state".into(),
                value: vec![1, 2, 3],
            }],
            seed: 7,
        }
    }

    #[test]
    fn study_roundtrip() {
        let study = StudyProto {
            name: "studies/1".into(),
            display_name: "cifar10".into(),
            state: StudyState::Active,
            spec: sample_spec(),
            created_ms: 1234,
        };
        let back: StudyProto = decode(&encode(&study)).unwrap();
        assert_eq!(back, study);
    }

    #[test]
    fn trial_roundtrip_with_all_fields() {
        let trial = TrialProto {
            id: 99,
            state: TrialState::Completed,
            parameters: vec![
                TrialParameter {
                    parameter_id: "lr".into(),
                    value: ParamValue::F64(0.01),
                },
                TrialParameter {
                    parameter_id: "model".into(),
                    value: ParamValue::Str("dnn".into()),
                },
                TrialParameter {
                    parameter_id: "layers".into(),
                    value: ParamValue::I64(-3),
                },
                TrialParameter {
                    parameter_id: "use_bn".into(),
                    value: ParamValue::Bool(true),
                },
            ],
            final_measurement: Some(Measurement {
                step_count: 100,
                elapsed_secs: 12.5,
                metrics: vec![Metric { metric_id: "acc".into(), value: 0.93 }],
            }),
            measurements: vec![Measurement {
                step_count: 50,
                elapsed_secs: 6.0,
                metrics: vec![Metric { metric_id: "acc".into(), value: 0.81 }],
            }],
            client_id: "worker-3".into(),
            infeasibility_reason: String::new(),
            metadata: vec![MetadataItem {
                namespace: String::new(),
                key: "ckpt".into(),
                value: b"path".to_vec(),
            }],
            created_ms: 10,
            completed_ms: 20,
        };
        let back: TrialProto = decode(&encode(&trial)).unwrap();
        assert_eq!(back, trial);
    }

    #[test]
    fn operation_roundtrip() {
        let op = OperationProto {
            name: "operations/5".into(),
            kind: OperationKind::EarlyStopping,
            study_name: "studies/1".into(),
            client_id: "w0".into(),
            done: true,
            error: "policy exploded".into(),
            trials: vec![TrialProto::default()],
            count: 2,
            trial_ids: vec![17, 0, 23],
            stop_decisions: vec![
                TrialStopDecision {
                    trial_id: 17,
                    should_stop: true,
                    reason: "below median".into(),
                },
                TrialStopDecision::default(),
            ],
            created_ms: 42,
        };
        let back: OperationProto = decode(&encode(&op)).unwrap();
        assert_eq!(back, op);
    }

    #[test]
    fn v1_operation_verdict_survives_decode() {
        // Hand-encode a v1-shaped operation: single trial id in field 9
        // and the verdict as the retired bool field 10. Replaying an old
        // WAL must not drop an acknowledged stop decision.
        let mut w = Writer::new();
        w.str(1, "operations/9");
        w.u64(2, OperationKind::EarlyStopping.as_u64());
        w.bool(5, true);
        w.u64(9, 33);
        w.bool(10, true);
        let op: OperationProto = decode(&w.into_bytes()).unwrap();
        assert_eq!(op.trial_ids, vec![33]);
        assert_eq!(op.stop_decisions.len(), 1);
        assert!(op.stop_decisions[0].should_stop);
        assert_eq!(op.stop_decisions[0].trial_id, 33);
    }

    #[test]
    fn batched_early_stopping_request_roundtrip() {
        let req = CheckEarlyStoppingRequest {
            study_name: "studies/3".into(),
            trial_ids: vec![1, 2, 99],
        };
        let back: CheckEarlyStoppingRequest = decode(&encode(&req)).unwrap();
        assert_eq!(back, req);
        // Empty = "all ACTIVE": survives the roundtrip as empty.
        let all = CheckEarlyStoppingRequest {
            study_name: "studies/3".into(),
            trial_ids: vec![],
        };
        let back: CheckEarlyStoppingRequest = decode(&encode(&all)).unwrap();
        assert_eq!(back, all);
    }

    #[test]
    fn list_studies_pagination_fields_roundtrip() {
        let req = ListStudiesRequest {
            page_size: 25,
            page_token: "3:studies/17".into(),
        };
        let back: ListStudiesRequest = decode(&encode(&req)).unwrap();
        assert_eq!(back, req);
        let resp = ListStudiesResponse {
            studies: vec![StudyProto::default()],
            next_page_token: "0:studies/2".into(),
        };
        let back: ListStudiesResponse = decode(&encode(&resp)).unwrap();
        assert_eq!(back, resp);
    }

    #[test]
    fn request_messages_roundtrip() {
        let req = SuggestTrialsRequest {
            study_name: "studies/9".into(),
            count: 4,
            client_id: "client-a".into(),
        };
        let back: SuggestTrialsRequest = decode(&encode(&req)).unwrap();
        assert_eq!(back, req);

        let req = CompleteTrialRequest {
            study_name: "studies/9".into(),
            trial_id: 3,
            final_measurement: None,
            infeasible: true,
            infeasibility_reason: "nan loss".into(),
        };
        let back: CompleteTrialRequest = decode(&encode(&req)).unwrap();
        assert_eq!(back, req);

        let req = UpdateMetadataRequest {
            study_name: "studies/9".into(),
            updates: vec![UnitMetadataUpdate {
                trial_id: 0,
                new_trial_index: 0,
                item: Some(MetadataItem {
                    namespace: "evo".into(),
                    key: "population".into(),
                    value: vec![9; 100],
                }),
            }],
        };
        let back: UpdateMetadataRequest = decode(&encode(&req)).unwrap();
        assert_eq!(back, req);
    }

    #[test]
    fn wait_operation_and_metrics_roundtrip() {
        let req = WaitOperationRequest {
            name: "operations/4".into(),
            timeout_ms: 12_500,
        };
        let back: WaitOperationRequest = decode(&encode(&req)).unwrap();
        assert_eq!(back, req);

        let m = ServiceMetricsResponse {
            policy_runs: 3,
            suggest_ops_served: 11,
            in_flight_policy_jobs: 7,
            errors: 1,
            wait_wakeups: 5,
            wait_wakeup_mean_us: 420,
            active_connections: 100,
            parked_responses: 9,
            connections_total: 250,
            requests: 10_000,
            report: "frontend: ...".into(),
            counters: vec![MetricPointProto {
                name: "errors".into(),
                value: 1,
            }],
            gauges: vec![MetricPointProto {
                name: "in_flight_policy_jobs".into(),
                value: 7,
            }],
            histograms: vec![MetricHistogramProto {
                name: "method.SuggestTrials".into(),
                count: 4,
                sum_us: 1000,
                p50_us: 256,
                p99_us: 512,
                buckets: vec![0, 1, 3],
            }],
        };
        let back: ServiceMetricsResponse = decode(&encode(&m)).unwrap();
        assert_eq!(back, m);
        assert!((back.histograms[0].mean_us() - 250.0).abs() < f64::EPSILON);
    }

    #[test]
    fn list_trials_pagination_fields_roundtrip() {
        let req = ListTrialsRequest {
            study_name: "studies/2".into(),
            page_size: 100,
            page_token: "57".into(),
        };
        let back: ListTrialsRequest = decode(&encode(&req)).unwrap();
        assert_eq!(back, req);
        let resp = ListTrialsResponse {
            trials: vec![TrialProto::default()],
            next_page_token: "1".into(),
        };
        let back: ListTrialsResponse = decode(&encode(&resp)).unwrap();
        assert_eq!(back, resp);
        // A v1 request (no pagination fields) decodes with the zero
        // values that select the full listing.
        let v1 = ListTrialsRequest { study_name: "studies/2".into(), ..Default::default() };
        let back: ListTrialsRequest = decode(&encode(&v1)).unwrap();
        assert_eq!(back.page_size, 0);
        assert!(back.page_token.is_empty());
    }

    #[test]
    fn param_value_missing_oneof_is_error() {
        let r: Result<ParamValue, _> = decode(&[]);
        assert!(r.is_err());
    }

    #[test]
    fn deeply_nested_conditionals_roundtrip() {
        // Build a 5-deep conditional chain.
        let mut spec = ParameterSpecProto {
            parameter_id: "leaf".into(),
            kind: ParameterKind::Double { min: 0.0, max: 1.0 },
            scale_type: ScaleType::Linear,
            conditional_children: vec![],
        };
        for depth in 0..5 {
            spec = ParameterSpecProto {
                parameter_id: format!("level{depth}"),
                kind: ParameterKind::Categorical { values: vec!["on".into(), "off".into()] },
                scale_type: ScaleType::Linear,
                conditional_children: vec![ConditionalParameterSpec {
                    parent_values: ParentValues { values: vec![ParamValue::Str("on".into())] },
                    spec,
                }],
            };
        }
        let back: ParameterSpecProto = decode(&encode(&spec)).unwrap();
        assert_eq!(back, spec);
    }

    #[test]
    fn trace_trailer_roundtrips_and_is_invisible_to_decoders() {
        use crate::util::trace::TraceCtx;
        let req = SuggestTrialsRequest {
            study_name: "studies/1".into(),
            count: 2,
            client_id: "w0".into(),
        };
        let mut payload = encode(&req);
        let bare_len = payload.len();
        append_trace_context(&mut payload, TraceCtx { trace_id: 7, span_id: 9 });
        assert!(payload.len() > bare_len);
        // The request decodes unchanged (trailer skipped as unknown).
        let back: SuggestTrialsRequest = decode(&payload).unwrap();
        assert_eq!(back, req);
        // The trailer extracts without touching the request decoder.
        let ctx = extract_trace_context(&payload).unwrap();
        assert_eq!(ctx, TraceCtx { trace_id: 7, span_id: 9 });
        // Payloads without a trailer (every v1 client) yield None.
        assert!(extract_trace_context(&encode(&req)).is_none());
        // A zero trace id is "absent", and garbage payloads are None,
        // not an error.
        let mut zeroed = encode(&req);
        append_trace_context(&mut zeroed, TraceCtx { trace_id: 0, span_id: 4 });
        assert!(extract_trace_context(&zeroed).is_none());
        assert!(extract_trace_context(&[0xFF, 0xFF, 0xFF]).is_none());
    }

    #[test]
    fn get_traces_messages_roundtrip() {
        let resp = GetTracesResponse {
            traces: vec![TraceProto {
                trace_id: 42,
                duration_us: 1234,
                spans: vec![SpanProto {
                    span_id: 1,
                    parent_id: 0,
                    name: "rpc:SuggestTrials".into(),
                    start_us: 10,
                    duration_us: 1200,
                }],
            }],
        };
        let back: GetTracesResponse = decode(&encode(&resp)).unwrap();
        assert_eq!(back, resp);
        let req = GetTracesRequest { limit: 5, include_infra: true };
        let back: GetTracesRequest = decode(&encode(&req)).unwrap();
        assert_eq!(back, req);
    }
}
