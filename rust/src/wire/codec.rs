//! Tag-length-value field codec over [`super::varint`] — the protobuf wire
//! format: `tag = (field_number << 3) | wire_type` with wire types
//! 0 (varint), 1 (fixed64), 2 (length-delimited) and 5 (fixed32).
//! Unknown fields are skippable, giving forward/backward compatibility —
//! the property the paper leans on for mixed-version deployments.

use super::varint::{get_uvarint, put_uvarint, unzigzag, zigzag};

/// Wire-level decode errors.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireError {
    Truncated,
    BadVarint,
    BadWireType(u8),
    BadUtf8,
    MissingField(&'static str),
    BadEnum { name: &'static str, value: u64 },
    Malformed(&'static str),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated => write!(f, "truncated message"),
            WireError::BadVarint => write!(f, "invalid varint"),
            WireError::BadWireType(t) => write!(f, "invalid wire type {t}"),
            WireError::BadUtf8 => write!(f, "invalid utf-8 in string field"),
            WireError::MissingField(name) => write!(f, "missing required field {name}"),
            WireError::BadEnum { name, value } => {
                write!(f, "invalid enum value {value} for {name}")
            }
            WireError::Malformed(msg) => write!(f, "malformed message: {msg}"),
        }
    }
}

impl std::error::Error for WireError {}

pub const WT_VARINT: u8 = 0;
pub const WT_FIXED64: u8 = 1;
pub const WT_LEN: u8 = 2;
pub const WT_FIXED32: u8 = 5;

/// Message encoder.
#[derive(Debug, Default)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Append pre-encoded message bytes verbatim (used by transports that
    /// re-frame an already-encoded payload).
    pub fn raw_append(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    fn tag(&mut self, field: u32, wt: u8) {
        put_uvarint(&mut self.buf, ((field as u64) << 3) | wt as u64);
    }

    /// Unsigned varint field. Zero values are still written (we do not use
    /// proto3 default-elision; explicitness keeps decode logic simple).
    pub fn u64(&mut self, field: u32, v: u64) {
        self.tag(field, WT_VARINT);
        put_uvarint(&mut self.buf, v);
    }

    /// Signed (zigzag) varint field.
    pub fn i64(&mut self, field: u32, v: i64) {
        self.tag(field, WT_VARINT);
        put_uvarint(&mut self.buf, zigzag(v));
    }

    pub fn bool(&mut self, field: u32, v: bool) {
        self.u64(field, v as u64);
    }

    /// Little-endian IEEE-754 double field.
    pub fn f64(&mut self, field: u32, v: f64) {
        self.tag(field, WT_FIXED64);
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f32(&mut self, field: u32, v: f32) {
        self.tag(field, WT_FIXED32);
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn str(&mut self, field: u32, v: &str) {
        self.bytes(field, v.as_bytes());
    }

    pub fn bytes(&mut self, field: u32, v: &[u8]) {
        self.tag(field, WT_LEN);
        put_uvarint(&mut self.buf, v.len() as u64);
        self.buf.extend_from_slice(v);
    }

    /// Nested message field.
    pub fn msg<M: WireMessage>(&mut self, field: u32, m: &M) {
        let inner = encode(m);
        self.bytes(field, &inner);
    }

    /// Repeated nested messages.
    pub fn msgs<M: WireMessage>(&mut self, field: u32, ms: &[M]) {
        for m in ms {
            self.msg(field, m);
        }
    }

    /// Packed repeated f64 (wire type 2).
    pub fn f64s_packed(&mut self, field: u32, vs: &[f64]) {
        if vs.is_empty() {
            return;
        }
        self.tag(field, WT_LEN);
        put_uvarint(&mut self.buf, (vs.len() * 8) as u64);
        for v in vs {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }
}

/// One decoded field.
#[derive(Debug)]
pub enum Field<'a> {
    Varint(u64),
    Fixed64([u8; 8]),
    Fixed32([u8; 4]),
    Len(&'a [u8]),
}

impl<'a> Field<'a> {
    pub fn as_u64(&self) -> Result<u64, WireError> {
        match self {
            Field::Varint(v) => Ok(*v),
            _ => Err(WireError::Malformed("expected varint field")),
        }
    }

    pub fn as_i64(&self) -> Result<i64, WireError> {
        Ok(unzigzag(self.as_u64()?))
    }

    pub fn as_bool(&self) -> Result<bool, WireError> {
        Ok(self.as_u64()? != 0)
    }

    pub fn as_f64(&self) -> Result<f64, WireError> {
        match self {
            Field::Fixed64(b) => Ok(f64::from_le_bytes(*b)),
            _ => Err(WireError::Malformed("expected fixed64 field")),
        }
    }

    pub fn as_f32(&self) -> Result<f32, WireError> {
        match self {
            Field::Fixed32(b) => Ok(f32::from_le_bytes(*b)),
            _ => Err(WireError::Malformed("expected fixed32 field")),
        }
    }

    pub fn as_bytes(&self) -> Result<&'a [u8], WireError> {
        match self {
            Field::Len(b) => Ok(b),
            _ => Err(WireError::Malformed("expected length-delimited field")),
        }
    }

    pub fn as_str(&self) -> Result<&'a str, WireError> {
        std::str::from_utf8(self.as_bytes()?).map_err(|_| WireError::BadUtf8)
    }

    pub fn as_string(&self) -> Result<String, WireError> {
        Ok(self.as_str()?.to_string())
    }

    /// Decode a nested message from this field.
    pub fn as_msg<M: WireMessage>(&self) -> Result<M, WireError> {
        decode(self.as_bytes()?)
    }

    pub fn as_f64s_packed(&self) -> Result<Vec<f64>, WireError> {
        let b = self.as_bytes()?;
        if b.len() % 8 != 0 {
            return Err(WireError::Malformed("packed f64 length not multiple of 8"));
        }
        Ok(b.chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

/// Field-by-field reader.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    /// Next (field_number, field), or None at end of buffer.
    pub fn next_field(&mut self) -> Result<Option<(u32, Field<'a>)>, WireError> {
        if self.pos >= self.buf.len() {
            return Ok(None);
        }
        let (tag, n) = get_uvarint(&self.buf[self.pos..]).ok_or(WireError::BadVarint)?;
        self.pos += n;
        let field = (tag >> 3) as u32;
        let wt = (tag & 7) as u8;
        let value = match wt {
            WT_VARINT => {
                let (v, n) = get_uvarint(&self.buf[self.pos..]).ok_or(WireError::BadVarint)?;
                self.pos += n;
                Field::Varint(v)
            }
            WT_FIXED64 => {
                let end = self.pos + 8;
                if end > self.buf.len() {
                    return Err(WireError::Truncated);
                }
                let mut b = [0u8; 8];
                b.copy_from_slice(&self.buf[self.pos..end]);
                self.pos = end;
                Field::Fixed64(b)
            }
            WT_FIXED32 => {
                let end = self.pos + 4;
                if end > self.buf.len() {
                    return Err(WireError::Truncated);
                }
                let mut b = [0u8; 4];
                b.copy_from_slice(&self.buf[self.pos..end]);
                self.pos = end;
                Field::Fixed32(b)
            }
            WT_LEN => {
                let (len, n) = get_uvarint(&self.buf[self.pos..]).ok_or(WireError::BadVarint)?;
                self.pos += n;
                let end = self.pos + len as usize;
                if end > self.buf.len() {
                    return Err(WireError::Truncated);
                }
                let slice = &self.buf[self.pos..end];
                self.pos = end;
                Field::Len(slice)
            }
            other => return Err(WireError::BadWireType(other)),
        };
        Ok(Some((field, value)))
    }
}

/// A message that can be encoded to / decoded from the wire format.
pub trait WireMessage: Sized {
    fn encode_fields(&self, w: &mut Writer);
    fn decode_fields(r: &mut Reader) -> Result<Self, WireError>;
}

/// Encode a message to bytes.
pub fn encode<M: WireMessage>(m: &M) -> Vec<u8> {
    let mut w = Writer::new();
    m.encode_fields(&mut w);
    w.into_bytes()
}

/// Decode a message from bytes.
pub fn decode<M: WireMessage>(buf: &[u8]) -> Result<M, WireError> {
    let mut r = Reader::new(buf);
    M::decode_fields(&mut r)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug, Default, PartialEq, Clone)]
    struct Inner {
        x: i64,
        tags: Vec<String>,
    }

    impl WireMessage for Inner {
        fn encode_fields(&self, w: &mut Writer) {
            w.i64(1, self.x);
            for t in &self.tags {
                w.str(2, t);
            }
        }
        fn decode_fields(r: &mut Reader) -> Result<Self, WireError> {
            let mut m = Inner::default();
            while let Some((f, v)) = r.next_field()? {
                match f {
                    1 => m.x = v.as_i64()?,
                    2 => m.tags.push(v.as_string()?),
                    _ => {}
                }
            }
            Ok(m)
        }
    }

    #[derive(Debug, Default, PartialEq)]
    struct Outer {
        id: u64,
        score: f64,
        flag: bool,
        inner: Option<Inner>,
        many: Vec<Inner>,
        data: Vec<f64>,
    }

    impl WireMessage for Outer {
        fn encode_fields(&self, w: &mut Writer) {
            w.u64(1, self.id);
            w.f64(2, self.score);
            w.bool(3, self.flag);
            if let Some(inner) = &self.inner {
                w.msg(4, inner);
            }
            w.msgs(5, &self.many);
            w.f64s_packed(6, &self.data);
        }
        fn decode_fields(r: &mut Reader) -> Result<Self, WireError> {
            let mut m = Outer::default();
            while let Some((f, v)) = r.next_field()? {
                match f {
                    1 => m.id = v.as_u64()?,
                    2 => m.score = v.as_f64()?,
                    3 => m.flag = v.as_bool()?,
                    4 => m.inner = Some(v.as_msg()?),
                    5 => m.many.push(v.as_msg()?),
                    6 => m.data = v.as_f64s_packed()?,
                    _ => {}
                }
            }
            Ok(m)
        }
    }

    #[test]
    fn nested_roundtrip() {
        let m = Outer {
            id: 42,
            score: -1.25e10,
            flag: true,
            inner: Some(Inner { x: -7, tags: vec!["a".into(), "b\n\"".into()] }),
            many: vec![
                Inner { x: 0, tags: vec![] },
                Inner { x: i64::MIN, tags: vec!["😀".into()] },
            ],
            data: vec![0.0, 1.5, f64::MAX],
        };
        let bytes = encode(&m);
        let back: Outer = decode(&bytes).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn unknown_fields_are_skipped() {
        // Encode Outer, then decode as Inner: all Outer fields have numbers
        // Inner ignores or reads compatibly; must not error.
        let mut w = Writer::new();
        w.u64(99, 7);
        w.f64(98, 1.0);
        w.str(97, "ignored");
        w.i64(1, -3);
        let m: Inner = decode(&w.into_bytes()).unwrap();
        assert_eq!(m.x, -3);
    }

    #[test]
    fn truncated_input_errors() {
        let m = Inner { x: 5, tags: vec!["hello".into()] };
        let bytes = encode(&m);
        for cut in 1..bytes.len() {
            // Every strict prefix must either decode to something valid
            // or produce an error, never panic.
            let _ = decode::<Inner>(&bytes[..cut]);
        }
        // A length-delimited field whose length exceeds the buffer errors.
        let mut w = Writer::new();
        w.bytes(1, &[1, 2, 3]);
        let mut bad = w.into_bytes();
        bad.truncate(bad.len() - 1);
        assert!(decode::<Inner>(&bad).is_err());
    }

    #[test]
    fn wrong_wire_type_is_error() {
        let mut w = Writer::new();
        w.str(1, "not a varint");
        let r: Result<Inner, _> = decode(&w.into_bytes());
        assert!(r.is_err());
    }

    #[test]
    fn empty_message_decodes_to_default() {
        let m: Inner = decode(&[]).unwrap();
        assert_eq!(m, Inner::default());
    }
}
