//! Binary wire format and RPC framing.
//!
//! OSS Vizier's API is defined in terms of Protocol Buffers carried over
//! gRPC (paper §3.1). The vendored registry has neither `prost` nor
//! `tonic`, so this module reimplements the protobuf **wire format**
//! (varints, zigzag, tag-length-value fields, nested messages, unknown-field
//! skipping) from scratch and defines the Vizier message schema on top of it
//! (`messages`), plus a length-prefixed RPC framing (`framing`) used by the
//! TCP transport. The architectural property the paper relies on — a
//! language-neutral binary client/server boundary — is preserved: any
//! language can implement this codec in a few hundred lines.
//!
//! The framing layer speaks two protocols on one port: the original
//! blocking v1 and the multiplexed/streaming v2 (correlation-id frames,
//! `HELLO` negotiation, watch streams, `CANCEL`). The full wire spec —
//! frame layouts, handshake, correlation-id rules, stream lifecycles —
//! is in `rust/docs/WIRE.md`.

pub mod codec;
pub mod framing;
pub mod messages;
pub mod varint;

pub use codec::{Reader, WireError, WireMessage, Writer};
