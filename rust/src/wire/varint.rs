//! LEB128 varint and zigzag encoding (protobuf-compatible).

/// Append `v` as a base-128 varint.
pub fn put_uvarint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7F) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

/// Decode a varint from `buf`, returning (value, bytes consumed).
pub fn get_uvarint(buf: &[u8]) -> Option<(u64, usize)> {
    let mut v: u64 = 0;
    for (i, &b) in buf.iter().enumerate().take(10) {
        if i == 9 && b > 1 {
            return None; // overflow past 64 bits
        }
        v |= ((b & 0x7F) as u64) << (7 * i);
        if b & 0x80 == 0 {
            return Some((v, i + 1));
        }
    }
    None
}

/// Zigzag-encode a signed integer (small magnitudes -> small varints).
pub fn zigzag(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

/// Inverse of [`zigzag`].
pub fn unzigzag(v: u64) -> i64 {
    ((v >> 1) as i64) ^ -((v & 1) as i64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn varint_roundtrip_edges() {
        for v in [0u64, 1, 127, 128, 255, 300, 1 << 21, u64::MAX / 2, u64::MAX] {
            let mut buf = Vec::new();
            put_uvarint(&mut buf, v);
            let (back, n) = get_uvarint(&buf).unwrap();
            assert_eq!(back, v);
            assert_eq!(n, buf.len());
        }
    }

    #[test]
    fn varint_known_encodings() {
        let mut buf = Vec::new();
        put_uvarint(&mut buf, 300);
        assert_eq!(buf, vec![0xAC, 0x02]); // protobuf docs example
    }

    #[test]
    fn varint_rejects_truncated_and_overflow() {
        assert!(get_uvarint(&[0x80]).is_none());
        assert!(get_uvarint(&[]).is_none());
        // 11 continuation bytes = too long.
        assert!(get_uvarint(&[0xFF; 11]).is_none());
    }

    #[test]
    fn zigzag_roundtrip() {
        for v in [0i64, -1, 1, -2, 2, i64::MAX, i64::MIN, 123456, -987654] {
            assert_eq!(unzigzag(zigzag(v)), v);
        }
        // Known mapping from the protobuf spec.
        assert_eq!(zigzag(0), 0);
        assert_eq!(zigzag(-1), 1);
        assert_eq!(zigzag(1), 2);
        assert_eq!(zigzag(-2), 3);
    }
}
