//! RPC framing over a byte stream. Full spec: `rust/docs/WIRE.md`.
//!
//! **Protocol v1** — one in-flight request per connection:
//! requests are `[u32-le total_len][u8 method][payload]`, responses
//! `[u32-le total_len][u8 status][payload]` where status 0 = OK (payload is
//! the method's response message) and nonzero = error class (payload is a
//! UTF-8 error string). This is the transport-level analogue of gRPC's
//! framed messages in the paper's stack.
//!
//! **Protocol v2** — multiplexed + streaming: every frame is
//! `[u32-le total_len][u8 kind][u32-le correlation_id][body]` where `kind`
//! is one of [`FrameKind`]. Kind bytes live in `0xE0..=0xE6`, disjoint from
//! every v1 head byte (methods 1–20, Pythia 101/102, statuses 0–5), so the
//! two protocols share the `[len][head][rest]` prefix and one
//! [`FrameReader`] parses both: the first head byte a server sees decides
//! the connection's protocol forever (`HELLO` ⇒ v2, anything else ⇒ the
//! v1 path — no flag days, old clients keep working). See
//! [`parse_v2`]/[`encode_v2`] for the v2 layer on top of the shared reader.

use super::codec::{decode, encode, WireMessage};
use std::io::{Read, Write};

/// Maximum frame size (16 MiB) — guards the server against hostile or
/// corrupt length prefixes.
pub const MAX_FRAME: u32 = 16 * 1024 * 1024;

/// RPC method identifiers (one per Vizier service method, paper §3.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum Method {
    CreateStudy = 1,
    GetStudy = 2,
    ListStudies = 3,
    DeleteStudy = 4,
    LookupStudy = 5,
    SuggestTrials = 6,
    GetOperation = 7,
    AddMeasurement = 8,
    CompleteTrial = 9,
    ListTrials = 10,
    GetTrial = 11,
    DeleteTrial = 12,
    CheckEarlyStopping = 13,
    StopTrial = 14,
    ListOptimalTrials = 15,
    UpdateMetadata = 16,
    /// Health probe; empty request/response.
    Ping = 17,
    /// Long-poll an operation server-side until it is done or the
    /// request's deadline passes (replaces client-side `GetOperation`
    /// busy-polling on servers that support it).
    WaitOperation = 18,
    /// Service/front-end counters snapshot (coalescing ratios, in-flight
    /// policy jobs, parked responses) without shelling into the server.
    GetServiceMetrics = 19,
    /// Slowest-N recent request traces (span trees) from the in-process
    /// trace rings; empty when tracing is disabled.
    GetTraces = 20,
}

impl Method {
    pub fn from_u8(v: u8) -> Option<Method> {
        use Method::*;
        Some(match v {
            1 => CreateStudy,
            2 => GetStudy,
            3 => ListStudies,
            4 => DeleteStudy,
            5 => LookupStudy,
            6 => SuggestTrials,
            7 => GetOperation,
            8 => AddMeasurement,
            9 => CompleteTrial,
            10 => ListTrials,
            11 => GetTrial,
            12 => DeleteTrial,
            13 => CheckEarlyStopping,
            14 => StopTrial,
            15 => ListOptimalTrials,
            16 => UpdateMetadata,
            17 => Ping,
            18 => WaitOperation,
            19 => GetServiceMetrics,
            20 => GetTraces,
            _ => return None,
        })
    }
}

/// Response status codes (mirrors the gRPC codes the service uses).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Status {
    Ok = 0,
    NotFound = 1,
    InvalidArgument = 2,
    FailedPrecondition = 3,
    Internal = 4,
    Unimplemented = 5,
}

impl Status {
    pub fn from_u8(v: u8) -> Status {
        match v {
            0 => Status::Ok,
            1 => Status::NotFound,
            2 => Status::InvalidArgument,
            3 => Status::FailedPrecondition,
            5 => Status::Unimplemented,
            _ => Status::Internal,
        }
    }
}

/// Highest wire-protocol version this build speaks.
pub const WIRE_VERSION_MAX: u64 = 2;

/// v2 frame kinds. Values are chosen in `0xE0..=0xE6` so they can never
/// collide with a v1 head byte (request method ids 1–20 and Pythia
/// 101/102, response status bytes 0–5): the first head byte on a fresh
/// connection unambiguously selects the protocol.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum FrameKind {
    /// Version negotiation. Client sends `HELLO` (corr 0, body =
    /// [`crate::wire::messages::HelloProto`]) as its first frame; a v2
    /// server echoes `HELLO` with the highest mutually supported version.
    /// A v1 server answers with a v1 error status byte (or closes), which
    /// the client latches as "v1 peer" for the life of the endpoint.
    Hello = 0xE0,
    /// Unary request. Body = `[u8 method][request message]`.
    Request = 0xE1,
    /// Successful unary response. Body = response message.
    Response = 0xE2,
    /// One item of a server-push stream (e.g. a `WaitOperation` watch
    /// snapshot). Body = item message.
    StreamItem = 0xE3,
    /// Normal end of a stream. Empty body.
    StreamEnd = 0xE4,
    /// Terminal failure for a unary call *or* a stream. Body =
    /// `[u8 status][utf-8 message]`.
    Error = 0xE5,
    /// Client abandons the correlation id (dropped stream handle, caller
    /// timeout). Empty body; the server drops any pending work/watchers
    /// for the id and sends nothing further on it.
    Cancel = 0xE6,
}

impl FrameKind {
    pub fn from_u8(v: u8) -> Option<FrameKind> {
        use FrameKind::*;
        Some(match v {
            0xE0 => Hello,
            0xE1 => Request,
            0xE2 => Response,
            0xE3 => StreamItem,
            0xE4 => StreamEnd,
            0xE5 => Error,
            0xE6 => Cancel,
            _ => return None,
        })
    }
}

/// True when `head` (the byte after the length prefix) belongs to the v2
/// protocol. Used by servers to sniff the protocol from the first frame
/// and by clients to recognise a v1 peer's reply to `HELLO`.
pub fn is_v2_head(head: u8) -> bool {
    FrameKind::from_u8(head).is_some()
}

/// A parsed v2 frame: `(kind, correlation id, body)`.
#[derive(Debug, Clone, PartialEq)]
pub struct V2Frame {
    pub kind: FrameKind,
    pub corr: u32,
    pub body: Vec<u8>,
}

/// Split a `(head, payload)` pair produced by [`FrameReader`] /
/// [`read_frame`] into a v2 frame. `payload` must start with the 4-byte
/// little-endian correlation id.
pub fn parse_v2(head: u8, mut payload: Vec<u8>) -> Result<V2Frame, FrameError> {
    let kind = FrameKind::from_u8(head)
        .ok_or_else(|| FrameError::Protocol(format!("not a v2 frame kind: {head:#04x}")))?;
    if payload.len() < 4 {
        return Err(FrameError::Protocol(format!(
            "v2 frame too short for correlation id: {} bytes",
            payload.len()
        )));
    }
    let corr = u32::from_le_bytes([payload[0], payload[1], payload[2], payload[3]]);
    payload.drain(..4);
    Ok(V2Frame { kind, corr, body: payload })
}

/// Encode a complete v2 frame (length prefix included) into a buffer —
/// the building block for multiplexed writers that append frames to a
/// shared out-buffer under a lock.
pub fn encode_v2(kind: FrameKind, corr: u32, body: &[u8]) -> Result<Vec<u8>, FrameError> {
    let total = 1u64 + 4 + body.len() as u64;
    if total > MAX_FRAME as u64 {
        return Err(FrameError::TooLarge(total.min(u32::MAX as u64) as u32));
    }
    let mut out = Vec::with_capacity(4 + total as usize);
    out.extend_from_slice(&(total as u32).to_le_bytes());
    out.push(kind as u8);
    out.extend_from_slice(&corr.to_le_bytes());
    out.extend_from_slice(body);
    Ok(out)
}

/// Write a v2 frame to a stream (blocking writer path).
pub fn write_v2<W: Write>(
    w: &mut W,
    kind: FrameKind,
    corr: u32,
    body: &[u8],
) -> Result<(), FrameError> {
    let frame = encode_v2(kind, corr, body)?;
    w.write_all(&frame)?;
    w.flush()?;
    Ok(())
}

/// Encode a v2 `REQUEST` frame: body = `[method][encoded message]`.
pub fn encode_v2_request<M: WireMessage>(
    corr: u32,
    method: Method,
    msg: &M,
) -> Result<Vec<u8>, FrameError> {
    let payload = encode(msg);
    let mut body = Vec::with_capacity(1 + payload.len());
    body.push(method as u8);
    body.extend_from_slice(&payload);
    encode_v2(FrameKind::Request, corr, &body)
}

/// Encode a v2 `ERROR` frame: body = `[status][utf-8 message]`.
pub fn encode_v2_error(corr: u32, status: Status, message: &str) -> Result<Vec<u8>, FrameError> {
    let mut body = Vec::with_capacity(1 + message.len());
    body.push(status as u8);
    body.extend_from_slice(message.as_bytes());
    encode_v2(FrameKind::Error, corr, &body)
}

/// Decode the body of a v2 `ERROR` frame back into its `Rpc` error.
pub fn decode_v2_error(body: &[u8]) -> FrameError {
    if body.is_empty() {
        return FrameError::Protocol("empty v2 error body".into());
    }
    FrameError::Rpc {
        status: Status::from_u8(body[0]),
        message: String::from_utf8_lossy(&body[1..]).into_owned(),
    }
}

/// Transport-level errors.
#[derive(Debug)]
pub enum FrameError {
    Io(std::io::Error),
    TooLarge(u32),
    UnknownMethod(u8),
    Empty,
    Wire(super::codec::WireError),
    Rpc { status: Status, message: String },
    /// Malformed v2 frame (bad kind byte, missing correlation id, ...).
    Protocol(String),
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Io(e) => write!(f, "io error: {e}"),
            FrameError::TooLarge(n) => write!(f, "frame too large: {n} bytes"),
            FrameError::UnknownMethod(id) => write!(f, "unknown method id {id}"),
            FrameError::Empty => write!(f, "empty frame"),
            FrameError::Wire(e) => write!(f, "wire decode error: {e}"),
            FrameError::Rpc { status, message } => {
                write!(f, "rpc failed: {status:?}: {message}")
            }
            FrameError::Protocol(msg) => write!(f, "protocol error: {msg}"),
        }
    }
}

impl std::error::Error for FrameError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            FrameError::Io(e) => Some(e),
            FrameError::Wire(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for FrameError {
    fn from(e: std::io::Error) -> Self {
        FrameError::Io(e)
    }
}

impl From<super::codec::WireError> for FrameError {
    fn from(e: super::codec::WireError) -> Self {
        FrameError::Wire(e)
    }
}

/// Write a request frame.
pub fn write_request<W: Write, M: WireMessage>(
    w: &mut W,
    method: Method,
    msg: &M,
) -> Result<(), FrameError> {
    let payload = encode(msg);
    write_raw(w, method as u8, &payload)
}

/// Write an OK response frame.
pub fn write_ok<W: Write, M: WireMessage>(w: &mut W, msg: &M) -> Result<(), FrameError> {
    let payload = encode(msg);
    write_raw(w, Status::Ok as u8, &payload)
}

/// Write an error response frame.
pub fn write_err<W: Write>(w: &mut W, status: Status, message: &str) -> Result<(), FrameError> {
    write_raw(w, status as u8, message.as_bytes())
}

fn write_raw<W: Write>(w: &mut W, head: u8, payload: &[u8]) -> Result<(), FrameError> {
    let total = 1 + payload.len() as u32;
    if total > MAX_FRAME {
        return Err(FrameError::TooLarge(total));
    }
    w.write_all(&total.to_le_bytes())?;
    w.write_all(&[head])?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one frame: returns (head byte, payload).
pub fn read_frame<R: Read>(r: &mut R) -> Result<(u8, Vec<u8>), FrameError> {
    let mut len_buf = [0u8; 4];
    r.read_exact(&mut len_buf)?;
    let total = u32::from_le_bytes(len_buf);
    if total == 0 {
        return Err(FrameError::Empty);
    }
    if total > MAX_FRAME {
        return Err(FrameError::TooLarge(total));
    }
    let mut buf = vec![0u8; total as usize];
    r.read_exact(&mut buf)?;
    let head = buf[0];
    buf.drain(..1);
    Ok((head, buf))
}

/// Progress of an incremental frame read (see [`FrameReader`]).
#[derive(Debug)]
pub enum FrameProgress {
    /// A complete frame: (head byte, payload).
    Frame(u8, Vec<u8>),
    /// The stream would block mid-frame; call again when readable.
    Pending,
    /// Clean EOF on a frame boundary (client disconnected).
    Closed,
}

/// Granularity of body reads in [`FrameReader`]: bounds per-call stack
/// buffer size and the initial buffer reservation.
const READ_CHUNK: usize = 8 * 1024;

/// Resumable frame reader for non-blocking streams.
///
/// [`read_frame`] assumes a blocking reader and parks the calling thread
/// until the frame is complete — exactly what a bounded worker pool must
/// not do when a slow or malicious client sends half a frame and stalls.
/// `FrameReader` is the per-connection read-state machine instead: each
/// [`poll_frame`](Self::poll_frame) call consumes whatever bytes are
/// available, returns [`FrameProgress::Pending`] on `WouldBlock`, and
/// yields at most one complete frame so request boundaries stay aligned
/// with scheduling decisions (one frame = one worker-pool job).
///
/// Memory grows with bytes *actually received*, never with the
/// attacker-controlled length prefix: a fleet of connections each
/// claiming a [`MAX_FRAME`]-sized body and then stalling costs only the
/// few bytes they really sent, not 16 MiB per connection.
#[derive(Debug, Default)]
pub struct FrameReader {
    len_buf: [u8; 4],
    len_got: usize,
    /// Head byte (method/status), read into its own slot so the payload
    /// never needs an O(len) shift to strip it.
    head: u8,
    head_got: bool,
    /// Payload bytes expected after the head byte.
    expected: usize,
    payload: Vec<u8>,
}

impl FrameReader {
    pub fn new() -> Self {
        Self::default()
    }

    /// True when a frame has been started but not finished (a stalled
    /// client mid-frame).
    pub fn mid_frame(&self) -> bool {
        self.len_got > 0
    }

    fn complete(&mut self) -> FrameProgress {
        let payload = std::mem::take(&mut self.payload);
        let head = self.head;
        self.len_got = 0;
        self.head_got = false;
        self.expected = 0;
        FrameProgress::Frame(head, payload)
    }

    /// Drive the state machine with whatever `r` has buffered. `r` should
    /// be a non-blocking stream (a blocking one degrades to `read_frame`
    /// behaviour). EOF inside a frame is an error; EOF on a frame
    /// boundary is [`FrameProgress::Closed`].
    pub fn poll_frame<R: Read>(&mut self, r: &mut R) -> Result<FrameProgress, FrameError> {
        loop {
            if self.len_got < 4 {
                match r.read(&mut self.len_buf[self.len_got..]) {
                    Ok(0) => {
                        return if self.len_got == 0 {
                            Ok(FrameProgress::Closed)
                        } else {
                            Err(FrameError::Io(std::io::Error::new(
                                std::io::ErrorKind::UnexpectedEof,
                                "eof inside frame length prefix",
                            )))
                        };
                    }
                    Ok(n) => {
                        self.len_got += n;
                        if self.len_got < 4 {
                            continue;
                        }
                        let total = u32::from_le_bytes(self.len_buf);
                        if total == 0 {
                            return Err(FrameError::Empty);
                        }
                        if total > MAX_FRAME {
                            return Err(FrameError::TooLarge(total));
                        }
                        self.head_got = false;
                        self.expected = (total - 1) as usize;
                        self.payload = Vec::with_capacity(self.expected.min(READ_CHUNK));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        return Ok(FrameProgress::Pending);
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(e) => return Err(FrameError::Io(e)),
                }
            } else if !self.head_got {
                let mut byte = [0u8; 1];
                match r.read(&mut byte) {
                    Ok(0) => {
                        return Err(FrameError::Io(std::io::Error::new(
                            std::io::ErrorKind::UnexpectedEof,
                            "eof inside frame head",
                        )));
                    }
                    Ok(_) => {
                        self.head = byte[0];
                        self.head_got = true;
                        if self.expected == 0 {
                            return Ok(self.complete());
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        return Ok(FrameProgress::Pending);
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(e) => return Err(FrameError::Io(e)),
                }
            } else {
                let mut chunk = [0u8; READ_CHUNK];
                let want = (self.expected - self.payload.len()).min(READ_CHUNK);
                match r.read(&mut chunk[..want]) {
                    Ok(0) => {
                        return Err(FrameError::Io(std::io::Error::new(
                            std::io::ErrorKind::UnexpectedEof,
                            "eof inside frame body",
                        )));
                    }
                    Ok(n) => {
                        self.payload.extend_from_slice(&chunk[..n]);
                        if self.payload.len() == self.expected {
                            return Ok(self.complete());
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        return Ok(FrameProgress::Pending);
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(e) => return Err(FrameError::Io(e)),
                }
            }
        }
    }
}

/// Read a request frame: returns (method, payload).
pub fn read_request<R: Read>(r: &mut R) -> Result<(Method, Vec<u8>), FrameError> {
    let (head, payload) = read_frame(r)?;
    let method = Method::from_u8(head).ok_or(FrameError::UnknownMethod(head))?;
    Ok((method, payload))
}

/// Read a response frame, decoding the payload on OK and converting error
/// statuses into [`FrameError::Rpc`].
pub fn read_response<R: Read, M: WireMessage>(r: &mut R) -> Result<M, FrameError> {
    let (head, payload) = read_frame(r)?;
    let status = Status::from_u8(head);
    if status == Status::Ok {
        Ok(decode(&payload)?)
    } else {
        Err(FrameError::Rpc {
            status,
            message: String::from_utf8_lossy(&payload).into_owned(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wire::messages::{GetStudyRequest, StudyProto, StudyResponse};
    use std::io::Cursor;

    #[test]
    fn request_roundtrip() {
        let mut buf = Vec::new();
        let req = GetStudyRequest { name: "studies/1".into() };
        write_request(&mut buf, Method::GetStudy, &req).unwrap();
        let mut cur = Cursor::new(buf);
        let (method, payload) = read_request(&mut cur).unwrap();
        assert_eq!(method, Method::GetStudy);
        let back: GetStudyRequest = decode(&payload).unwrap();
        assert_eq!(back, req);
    }

    #[test]
    fn ok_response_roundtrip() {
        let mut buf = Vec::new();
        let resp = StudyResponse {
            study: StudyProto { name: "studies/1".into(), ..Default::default() },
        };
        write_ok(&mut buf, &resp).unwrap();
        let mut cur = Cursor::new(buf);
        let back: StudyResponse = read_response(&mut cur).unwrap();
        assert_eq!(back, resp);
    }

    #[test]
    fn error_response_surfaces_status() {
        let mut buf = Vec::new();
        write_err(&mut buf, Status::NotFound, "no such study").unwrap();
        let mut cur = Cursor::new(buf);
        let err = read_response::<_, StudyResponse>(&mut cur).unwrap_err();
        match err {
            FrameError::Rpc { status, message } => {
                assert_eq!(status, Status::NotFound);
                assert_eq!(message, "no such study");
            }
            other => panic!("unexpected error {other:?}"),
        }
    }

    #[test]
    fn oversized_frame_rejected() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(MAX_FRAME + 1).to_le_bytes());
        buf.push(0);
        let mut cur = Cursor::new(buf);
        assert!(matches!(
            read_frame(&mut cur),
            Err(FrameError::TooLarge(_))
        ));
    }

    #[test]
    fn truncated_stream_is_io_error() {
        let mut buf = Vec::new();
        write_err(&mut buf, Status::Ok, "x").unwrap();
        buf.truncate(buf.len() - 1);
        let mut cur = Cursor::new(buf);
        assert!(matches!(read_frame(&mut cur), Err(FrameError::Io(_))));
    }

    #[test]
    fn unknown_method_rejected() {
        let mut buf = Vec::new();
        write_raw(&mut buf, 200, b"").unwrap();
        let mut cur = Cursor::new(buf);
        assert!(matches!(
            read_request(&mut cur),
            Err(FrameError::UnknownMethod(200))
        ));
    }

    /// A reader that yields its script one chunk per call, returning
    /// `WouldBlock` between chunks (mimics a non-blocking socket fed by a
    /// slow client).
    struct Drip {
        chunks: Vec<Vec<u8>>,
        next: usize,
        starved: bool,
        eof_at_end: bool,
    }

    impl Drip {
        fn new(bytes: &[u8], chunk: usize, eof_at_end: bool) -> Self {
            Self {
                chunks: bytes.chunks(chunk.max(1)).map(|c| c.to_vec()).collect(),
                next: 0,
                starved: false,
                eof_at_end,
            }
        }
    }

    impl std::io::Read for Drip {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            if self.next >= self.chunks.len() {
                return if self.eof_at_end {
                    Ok(0)
                } else {
                    Err(std::io::ErrorKind::WouldBlock.into())
                };
            }
            if !self.starved {
                self.starved = true;
                return Err(std::io::ErrorKind::WouldBlock.into());
            }
            self.starved = false;
            let chunk = &self.chunks[self.next];
            let n = chunk.len().min(buf.len());
            buf[..n].copy_from_slice(&chunk[..n]);
            if n == chunk.len() {
                self.next += 1;
            } else {
                self.chunks[self.next].drain(..n);
            }
            Ok(n)
        }
    }

    #[test]
    fn frame_reader_resumes_across_partial_reads() {
        let mut wire = Vec::new();
        write_request(&mut wire, Method::GetStudy, &GetStudyRequest { name: "studies/7".into() })
            .unwrap();
        // Byte-at-a-time with a WouldBlock before every byte.
        let mut drip = Drip::new(&wire, 1, false);
        let mut fr = FrameReader::new();
        let mut pendings = 0;
        loop {
            match fr.poll_frame(&mut drip).unwrap() {
                FrameProgress::Frame(head, payload) => {
                    assert_eq!(head, Method::GetStudy as u8);
                    let req: GetStudyRequest = decode(&payload).unwrap();
                    assert_eq!(req.name, "studies/7");
                    break;
                }
                FrameProgress::Pending => pendings += 1,
                FrameProgress::Closed => panic!("unexpected close"),
            }
        }
        assert!(pendings >= wire.len(), "reader must park, not spin-block");
        assert!(!fr.mid_frame());
    }

    #[test]
    fn frame_reader_back_to_back_and_clean_close() {
        let mut wire = Vec::new();
        for i in 0..3u64 {
            write_request(
                &mut wire,
                Method::GetStudy,
                &GetStudyRequest { name: format!("studies/{i}") },
            )
            .unwrap();
        }
        let mut drip = Drip::new(&wire, 7, true);
        let mut fr = FrameReader::new();
        let mut seen = 0;
        loop {
            match fr.poll_frame(&mut drip).unwrap() {
                FrameProgress::Frame(_, payload) => {
                    let req: GetStudyRequest = decode(&payload).unwrap();
                    assert_eq!(req.name, format!("studies/{seen}"));
                    seen += 1;
                }
                FrameProgress::Pending => {}
                FrameProgress::Closed => break,
            }
        }
        assert_eq!(seen, 3);
    }

    #[test]
    fn frame_reader_mid_frame_states_and_eof() {
        let mut wire = Vec::new();
        write_err(&mut wire, Status::Ok, "hello").unwrap();
        // Stall after 2 bytes of the length prefix.
        let mut drip = Drip::new(&wire[..2], 2, false);
        let mut fr = FrameReader::new();
        assert!(matches!(fr.poll_frame(&mut drip).unwrap(), FrameProgress::Pending));
        while !matches!(fr.poll_frame(&mut drip).unwrap(), FrameProgress::Pending) {}
        assert!(fr.mid_frame());
        // EOF inside the frame is an error, not a clean close.
        let mut eof = Drip::new(&[], 1, true);
        assert!(matches!(fr.poll_frame(&mut eof), Err(FrameError::Io(_))));
    }

    #[test]
    fn frame_reader_does_not_preallocate_from_prefix() {
        // A (legal) max-sized length claim followed by a stall must not
        // cost MAX_FRAME of memory — only what actually arrived.
        let mut wire = Vec::new();
        wire.extend_from_slice(&MAX_FRAME.to_le_bytes());
        let mut drip = Drip::new(&wire, 4, false);
        let mut fr = FrameReader::new();
        loop {
            match fr.poll_frame(&mut drip).unwrap() {
                FrameProgress::Pending => {
                    if drip.next >= drip.chunks.len() {
                        break; // prefix fully consumed, client stalled
                    }
                }
                other => panic!("unexpected progress {other:?}"),
            }
        }
        assert!(fr.mid_frame());
        assert!(
            fr.payload.capacity() <= READ_CHUNK,
            "stalled 16 MiB claim must not preallocate (got {} bytes)",
            fr.payload.capacity()
        );
    }

    #[test]
    fn frame_reader_rejects_oversized_and_empty() {
        let mut bad = Vec::new();
        bad.extend_from_slice(&(MAX_FRAME + 1).to_le_bytes());
        let mut drip = Drip::new(&bad, 4, false);
        let mut fr = FrameReader::new();
        loop {
            match fr.poll_frame(&mut drip) {
                Err(FrameError::TooLarge(_)) => break,
                Ok(FrameProgress::Pending) => continue,
                other => panic!("expected TooLarge, got {other:?}"),
            }
        }
        let zero = 0u32.to_le_bytes();
        let mut drip = Drip::new(&zero, 4, false);
        let mut fr = FrameReader::new();
        loop {
            match fr.poll_frame(&mut drip) {
                Err(FrameError::Empty) => break,
                Ok(FrameProgress::Pending) => continue,
                other => panic!("expected Empty, got {other:?}"),
            }
        }
    }

    #[test]
    fn back_to_back_frames() {
        let mut buf = Vec::new();
        for i in 0..5u64 {
            write_request(
                &mut buf,
                Method::GetStudy,
                &GetStudyRequest { name: format!("studies/{i}") },
            )
            .unwrap();
        }
        let mut cur = Cursor::new(buf);
        for i in 0..5u64 {
            let (m, p) = read_request(&mut cur).unwrap();
            assert_eq!(m, Method::GetStudy);
            let req: GetStudyRequest = decode(&p).unwrap();
            assert_eq!(req.name, format!("studies/{i}"));
        }
    }

    #[test]
    fn v2_kind_bytes_disjoint_from_v1_heads() {
        for head in 0u8..=255 {
            let v1_method = Method::from_u8(head).is_some();
            let v1_status = head <= 5;
            let v1_pythia = head == 101 || head == 102;
            if v1_method || v1_status || v1_pythia {
                assert!(!is_v2_head(head), "head {head:#04x} is ambiguous");
            }
        }
        for kind in [
            FrameKind::Hello,
            FrameKind::Request,
            FrameKind::Response,
            FrameKind::StreamItem,
            FrameKind::StreamEnd,
            FrameKind::Error,
            FrameKind::Cancel,
        ] {
            assert!(is_v2_head(kind as u8));
            assert_eq!(FrameKind::from_u8(kind as u8), Some(kind));
        }
    }

    #[test]
    fn v2_frame_roundtrips_through_shared_reader() {
        let req = GetStudyRequest { name: "studies/42".into() };
        let wire = encode_v2_request(7, Method::GetStudy, &req).unwrap();
        // The v1 FrameReader parses the shared [len][head][rest] prefix.
        let mut drip = Drip::new(&wire, 3, false);
        let mut fr = FrameReader::new();
        let (head, payload) = loop {
            match fr.poll_frame(&mut drip).unwrap() {
                FrameProgress::Frame(h, p) => break (h, p),
                FrameProgress::Pending => {}
                FrameProgress::Closed => panic!("unexpected close"),
            }
        };
        let frame = parse_v2(head, payload).unwrap();
        assert_eq!(frame.kind, FrameKind::Request);
        assert_eq!(frame.corr, 7);
        assert_eq!(frame.body[0], Method::GetStudy as u8);
        let back: GetStudyRequest = decode(&frame.body[1..]).unwrap();
        assert_eq!(back, req);
    }

    #[test]
    fn v2_error_frame_roundtrip() {
        let wire = encode_v2_error(9, Status::NotFound, "no such study").unwrap();
        let mut cur = Cursor::new(wire);
        let (head, payload) = read_frame(&mut cur).unwrap();
        let frame = parse_v2(head, payload).unwrap();
        assert_eq!(frame.kind, FrameKind::Error);
        assert_eq!(frame.corr, 9);
        match decode_v2_error(&frame.body) {
            FrameError::Rpc { status, message } => {
                assert_eq!(status, Status::NotFound);
                assert_eq!(message, "no such study");
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn v2_frame_without_corr_id_rejected() {
        // A v2 kind byte with a body shorter than the correlation id.
        let mut wire = Vec::new();
        wire.extend_from_slice(&3u32.to_le_bytes());
        wire.push(FrameKind::Cancel as u8);
        wire.extend_from_slice(&[0, 0]);
        let mut cur = Cursor::new(wire);
        let (head, payload) = read_frame(&mut cur).unwrap();
        assert!(matches!(parse_v2(head, payload), Err(FrameError::Protocol(_))));
    }

    #[test]
    fn v2_stream_frames_roundtrip() {
        let mut wire = Vec::new();
        wire.extend_from_slice(&encode_v2(FrameKind::StreamItem, 3, b"item").unwrap());
        wire.extend_from_slice(&encode_v2(FrameKind::StreamEnd, 3, b"").unwrap());
        wire.extend_from_slice(&encode_v2(FrameKind::Cancel, 4, b"").unwrap());
        let mut cur = Cursor::new(wire);
        let (h, p) = read_frame(&mut cur).unwrap();
        let f = parse_v2(h, p).unwrap();
        assert_eq!((f.kind, f.corr, f.body.as_slice()), (FrameKind::StreamItem, 3, &b"item"[..]));
        let (h, p) = read_frame(&mut cur).unwrap();
        let f = parse_v2(h, p).unwrap();
        assert_eq!((f.kind, f.corr), (FrameKind::StreamEnd, 3));
        assert!(f.body.is_empty());
        let (h, p) = read_frame(&mut cur).unwrap();
        let f = parse_v2(h, p).unwrap();
        assert_eq!((f.kind, f.corr), (FrameKind::Cancel, 4));
    }
}
