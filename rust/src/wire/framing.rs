//! RPC framing over a byte stream.
//!
//! Requests: `[u32-le total_len][u8 method][payload]`.
//! Responses: `[u32-le total_len][u8 status][payload]` where status 0 = OK
//! (payload is the method's response message) and nonzero = error class
//! (payload is a UTF-8 error string). This is the transport-level analogue
//! of gRPC's framed messages in the paper's stack.

use super::codec::{decode, encode, WireMessage};
use std::io::{Read, Write};

/// Maximum frame size (16 MiB) — guards the server against hostile or
/// corrupt length prefixes.
pub const MAX_FRAME: u32 = 16 * 1024 * 1024;

/// RPC method identifiers (one per Vizier service method, paper §3.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum Method {
    CreateStudy = 1,
    GetStudy = 2,
    ListStudies = 3,
    DeleteStudy = 4,
    LookupStudy = 5,
    SuggestTrials = 6,
    GetOperation = 7,
    AddMeasurement = 8,
    CompleteTrial = 9,
    ListTrials = 10,
    GetTrial = 11,
    DeleteTrial = 12,
    CheckEarlyStopping = 13,
    StopTrial = 14,
    ListOptimalTrials = 15,
    UpdateMetadata = 16,
    /// Health probe; empty request/response.
    Ping = 17,
}

impl Method {
    pub fn from_u8(v: u8) -> Option<Method> {
        use Method::*;
        Some(match v {
            1 => CreateStudy,
            2 => GetStudy,
            3 => ListStudies,
            4 => DeleteStudy,
            5 => LookupStudy,
            6 => SuggestTrials,
            7 => GetOperation,
            8 => AddMeasurement,
            9 => CompleteTrial,
            10 => ListTrials,
            11 => GetTrial,
            12 => DeleteTrial,
            13 => CheckEarlyStopping,
            14 => StopTrial,
            15 => ListOptimalTrials,
            16 => UpdateMetadata,
            17 => Ping,
            _ => return None,
        })
    }
}

/// Response status codes (mirrors the gRPC codes the service uses).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Status {
    Ok = 0,
    NotFound = 1,
    InvalidArgument = 2,
    FailedPrecondition = 3,
    Internal = 4,
    Unimplemented = 5,
}

impl Status {
    pub fn from_u8(v: u8) -> Status {
        match v {
            0 => Status::Ok,
            1 => Status::NotFound,
            2 => Status::InvalidArgument,
            3 => Status::FailedPrecondition,
            5 => Status::Unimplemented,
            _ => Status::Internal,
        }
    }
}

/// Transport-level errors.
#[derive(Debug)]
pub enum FrameError {
    Io(std::io::Error),
    TooLarge(u32),
    UnknownMethod(u8),
    Empty,
    Wire(super::codec::WireError),
    Rpc { status: Status, message: String },
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Io(e) => write!(f, "io error: {e}"),
            FrameError::TooLarge(n) => write!(f, "frame too large: {n} bytes"),
            FrameError::UnknownMethod(id) => write!(f, "unknown method id {id}"),
            FrameError::Empty => write!(f, "empty frame"),
            FrameError::Wire(e) => write!(f, "wire decode error: {e}"),
            FrameError::Rpc { status, message } => {
                write!(f, "rpc failed: {status:?}: {message}")
            }
        }
    }
}

impl std::error::Error for FrameError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            FrameError::Io(e) => Some(e),
            FrameError::Wire(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for FrameError {
    fn from(e: std::io::Error) -> Self {
        FrameError::Io(e)
    }
}

impl From<super::codec::WireError> for FrameError {
    fn from(e: super::codec::WireError) -> Self {
        FrameError::Wire(e)
    }
}

/// Write a request frame.
pub fn write_request<W: Write, M: WireMessage>(
    w: &mut W,
    method: Method,
    msg: &M,
) -> Result<(), FrameError> {
    let payload = encode(msg);
    write_raw(w, method as u8, &payload)
}

/// Write an OK response frame.
pub fn write_ok<W: Write, M: WireMessage>(w: &mut W, msg: &M) -> Result<(), FrameError> {
    let payload = encode(msg);
    write_raw(w, Status::Ok as u8, &payload)
}

/// Write an error response frame.
pub fn write_err<W: Write>(w: &mut W, status: Status, message: &str) -> Result<(), FrameError> {
    write_raw(w, status as u8, message.as_bytes())
}

fn write_raw<W: Write>(w: &mut W, head: u8, payload: &[u8]) -> Result<(), FrameError> {
    let total = 1 + payload.len() as u32;
    if total > MAX_FRAME {
        return Err(FrameError::TooLarge(total));
    }
    w.write_all(&total.to_le_bytes())?;
    w.write_all(&[head])?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one frame: returns (head byte, payload).
pub fn read_frame<R: Read>(r: &mut R) -> Result<(u8, Vec<u8>), FrameError> {
    let mut len_buf = [0u8; 4];
    r.read_exact(&mut len_buf)?;
    let total = u32::from_le_bytes(len_buf);
    if total == 0 {
        return Err(FrameError::Empty);
    }
    if total > MAX_FRAME {
        return Err(FrameError::TooLarge(total));
    }
    let mut buf = vec![0u8; total as usize];
    r.read_exact(&mut buf)?;
    let head = buf[0];
    buf.drain(..1);
    Ok((head, buf))
}

/// Read a request frame: returns (method, payload).
pub fn read_request<R: Read>(r: &mut R) -> Result<(Method, Vec<u8>), FrameError> {
    let (head, payload) = read_frame(r)?;
    let method = Method::from_u8(head).ok_or(FrameError::UnknownMethod(head))?;
    Ok((method, payload))
}

/// Read a response frame, decoding the payload on OK and converting error
/// statuses into [`FrameError::Rpc`].
pub fn read_response<R: Read, M: WireMessage>(r: &mut R) -> Result<M, FrameError> {
    let (head, payload) = read_frame(r)?;
    let status = Status::from_u8(head);
    if status == Status::Ok {
        Ok(decode(&payload)?)
    } else {
        Err(FrameError::Rpc {
            status,
            message: String::from_utf8_lossy(&payload).into_owned(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wire::messages::{GetStudyRequest, StudyProto, StudyResponse};
    use std::io::Cursor;

    #[test]
    fn request_roundtrip() {
        let mut buf = Vec::new();
        let req = GetStudyRequest { name: "studies/1".into() };
        write_request(&mut buf, Method::GetStudy, &req).unwrap();
        let mut cur = Cursor::new(buf);
        let (method, payload) = read_request(&mut cur).unwrap();
        assert_eq!(method, Method::GetStudy);
        let back: GetStudyRequest = decode(&payload).unwrap();
        assert_eq!(back, req);
    }

    #[test]
    fn ok_response_roundtrip() {
        let mut buf = Vec::new();
        let resp = StudyResponse {
            study: StudyProto { name: "studies/1".into(), ..Default::default() },
        };
        write_ok(&mut buf, &resp).unwrap();
        let mut cur = Cursor::new(buf);
        let back: StudyResponse = read_response(&mut cur).unwrap();
        assert_eq!(back, resp);
    }

    #[test]
    fn error_response_surfaces_status() {
        let mut buf = Vec::new();
        write_err(&mut buf, Status::NotFound, "no such study").unwrap();
        let mut cur = Cursor::new(buf);
        let err = read_response::<_, StudyResponse>(&mut cur).unwrap_err();
        match err {
            FrameError::Rpc { status, message } => {
                assert_eq!(status, Status::NotFound);
                assert_eq!(message, "no such study");
            }
            other => panic!("unexpected error {other:?}"),
        }
    }

    #[test]
    fn oversized_frame_rejected() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(MAX_FRAME + 1).to_le_bytes());
        buf.push(0);
        let mut cur = Cursor::new(buf);
        assert!(matches!(
            read_frame(&mut cur),
            Err(FrameError::TooLarge(_))
        ));
    }

    #[test]
    fn truncated_stream_is_io_error() {
        let mut buf = Vec::new();
        write_err(&mut buf, Status::Ok, "x").unwrap();
        buf.truncate(buf.len() - 1);
        let mut cur = Cursor::new(buf);
        assert!(matches!(read_frame(&mut cur), Err(FrameError::Io(_))));
    }

    #[test]
    fn unknown_method_rejected() {
        let mut buf = Vec::new();
        write_raw(&mut buf, 200, b"").unwrap();
        let mut cur = Cursor::new(buf);
        assert!(matches!(
            read_request(&mut cur),
            Err(FrameError::UnknownMethod(200))
        ));
    }

    #[test]
    fn back_to_back_frames() {
        let mut buf = Vec::new();
        for i in 0..5u64 {
            write_request(
                &mut buf,
                Method::GetStudy,
                &GetStudyRequest { name: format!("studies/{i}") },
            )
            .unwrap();
        }
        let mut cur = Cursor::new(buf);
        for i in 0..5u64 {
            let (m, p) = read_request(&mut cur).unwrap();
            assert_eq!(m, Method::GetStudy);
            let req: GetStudyRequest = decode(&p).unwrap();
            assert_eq!(req.name, format!("studies/{i}"));
        }
    }
}
