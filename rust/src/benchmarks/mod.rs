//! Benchmark substrate: synthetic objectives (the standard BBOB-style
//! suite + multi-objective ZDT), a learning-curve simulator for
//! early-stopping studies, and a study-driver harness that records
//! convergence traces.
//!
//! The paper evaluates no algorithms (§8) — these workloads exist to
//! exercise and regenerate the *system* claims (experiment index in
//! DESIGN.md §7).

pub mod curve_sim;
pub mod objectives;
pub mod runner;

pub use curve_sim::CurveSimulator;
pub use objectives::Objective;
pub use runner::{run_study, StudyOutcome};
