//! Study driver: runs one policy against one objective through the real
//! service stack and records the convergence trace (best-so-far per
//! trial), wall-clock, and error counts.

use super::objectives::Objective;
use crate::client::{LocalTransport, VizierClient};
use crate::pyvizier::{Algorithm, Measurement, StudyConfig};
use crate::service::in_memory_service;
use crate::util::time::Stopwatch;

/// Result of one study run.
#[derive(Debug, Clone)]
pub struct StudyOutcome {
    pub objective: &'static str,
    pub algorithm: String,
    pub seed: u64,
    /// best-so-far objective value after each completed trial
    /// (minimization orientation).
    pub trace: Vec<f64>,
    pub wall_ms: f64,
    pub suggest_failures: usize,
}

impl StudyOutcome {
    pub fn best(&self) -> f64 {
        self.trace.last().copied().unwrap_or(f64::INFINITY)
    }

    /// First trial index reaching within `tol` of `target`, if any.
    pub fn trials_to_reach(&self, target: f64, tol: f64) -> Option<usize> {
        self.trace.iter().position(|&v| v <= target + tol).map(|i| i + 1)
    }
}

/// Run `budget` trials of `algorithm` on `objective` (single-objective,
/// minimization orientation) through an in-process service.
pub fn run_study(
    objective: Objective,
    d: usize,
    algorithm: Algorithm,
    seed: u64,
    budget: usize,
    batch: usize,
) -> StudyOutcome {
    assert!(!objective.is_multiobjective(), "use run_mo_study");
    let mut config = objective.study_config(d);
    config.algorithm = algorithm.clone();
    config.seed = seed;
    let service = in_memory_service(2);
    let transport = Box::new(LocalTransport::new(service));
    let mut client = VizierClient::load_or_create_study(
        transport,
        &format!("{}-{}-{}", objective.name(), algorithm.as_str(), seed),
        &config,
        "runner",
    )
    .expect("create study");

    let sw = Stopwatch::start();
    let mut trace = Vec::with_capacity(budget);
    let mut best = f64::INFINITY;
    let mut suggest_failures = 0;
    while trace.len() < budget {
        let want = batch.min(budget - trace.len());
        let suggestions = match client.get_suggestions(want) {
            Ok(s) => s,
            Err(_) => {
                suggest_failures += 1;
                if suggest_failures > 3 {
                    break;
                }
                continue;
            }
        };
        if suggestions.is_empty() {
            break;
        }
        for t in suggestions {
            let v = objective.evaluate(&t.parameters, d)[0].1;
            best = best.min(v);
            trace.push(best);
            client
                .complete_trial(t.id, Some(&Measurement::new(1).with_metric("value", v)))
                .expect("complete");
        }
    }
    StudyOutcome {
        objective: objective.name(),
        algorithm: algorithm.as_str().to_string(),
        seed,
        trace,
        wall_ms: sw.elapsed_millis_f64(),
        suggest_failures,
    }
}

/// Run a multi-objective study; returns the hypervolume trace (ZDT
/// reference point (1.1, 7)).
pub fn run_mo_study(
    objective: Objective,
    d: usize,
    seed: u64,
    budget: usize,
    batch: usize,
) -> (Vec<f64>, StudyConfig) {
    assert!(objective.is_multiobjective());
    let mut config = objective.study_config(d);
    config.algorithm = Algorithm::Nsga2;
    config.seed = seed;
    let service = in_memory_service(2);
    let transport = Box::new(LocalTransport::new(service));
    let mut client = VizierClient::load_or_create_study(
        transport,
        &format!("{}-{seed}", objective.name()),
        &config,
        "runner",
    )
    .expect("create study");

    let mut points: Vec<Vec<f64>> = Vec::new();
    let mut hv_trace = Vec::new();
    while hv_trace.len() < budget {
        let want = batch.min(budget - hv_trace.len());
        let suggestions = client.get_suggestions(want).expect("suggest");
        for t in suggestions {
            let metrics = objective.evaluate(&t.parameters, d);
            let mut m = Measurement::new(1);
            for (k, v) in &metrics {
                m.metrics.insert(k.clone(), *v);
            }
            client.complete_trial(t.id, Some(&m)).expect("complete");
            // Maximization orientation for the hypervolume helper.
            points.push(vec![-metrics[0].1, -metrics[1].1]);
            hv_trace.push(crate::pyvizier::pareto::hypervolume_2d(&points, &[-1.1, -7.0]));
        }
    }
    (hv_trace, config)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runner_produces_monotone_trace() {
        let outcome = run_study(Objective::Sphere, 3, Algorithm::RandomSearch, 1, 20, 4);
        assert_eq!(outcome.trace.len(), 20);
        for w in outcome.trace.windows(2) {
            assert!(w[1] <= w[0], "best-so-far must be monotone");
        }
        assert!(outcome.best().is_finite());
        assert_eq!(outcome.suggest_failures, 0);
    }

    #[test]
    fn informed_policies_beat_random_on_sphere() {
        // Small smoke version of the C-CONV experiment: median over seeds.
        let med = |alg: Algorithm| {
            let mut bests: Vec<f64> = (0..3)
                .map(|s| run_study(Objective::Sphere, 3, alg.clone(), s, 40, 4).best())
                .collect();
            bests.sort_by(|a, b| a.partial_cmp(b).unwrap());
            bests[1]
        };
        let random = med(Algorithm::RandomSearch);
        let evo = med(Algorithm::RegularizedEvolution);
        assert!(
            evo < random * 1.5,
            "evolution ({evo}) should be at least comparable to random ({random})"
        );
    }

    #[test]
    fn mo_runner_hypervolume_grows() {
        let (hv, _) = run_mo_study(Objective::Zdt1, 4, 3, 40, 8);
        assert_eq!(hv.len(), 40);
        assert!(hv.last().unwrap() > &hv[4], "hv {:?} -> {:?}", hv[4], hv.last());
    }
}
