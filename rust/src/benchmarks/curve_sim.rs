//! Learning-curve simulator: a synthetic stand-in for "tuning the
//! hyperparameters of a large ML model" (paper §2) that exercises
//! intermediate measurements, early stopping, noisy evaluations, and
//! transient failures — without training real models.
//!
//! A configuration (learning_rate, num_layers, optimizer) maps to a
//! saturating accuracy curve `plateau · (1 − exp(−step/tau))` plus noise;
//! the plateau peaks at lr = 10⁻², 3 layers, adam (same shape as the
//! test objective used throughout the policy tests).

use crate::pyvizier::{Measurement, MetricInformation, ParameterDict, StudyConfig};
use crate::util::rng::Pcg32;
use crate::wire::messages::{ScaleType, StoppingConfig, StoppingKind};

/// Simulator parameters.
#[derive(Debug, Clone)]
pub struct CurveSimulator {
    /// Total training steps per trial.
    pub max_steps: i64,
    /// Gaussian noise on each reported accuracy.
    pub noise_std: f64,
    /// Probability a step raises a *transient* failure (retryable).
    pub transient_failure_p: f64,
    /// Probability a config is fundamentally broken (infeasible).
    pub infeasible_p: f64,
}

impl Default for CurveSimulator {
    fn default() -> Self {
        Self {
            max_steps: 20,
            noise_std: 0.01,
            transient_failure_p: 0.0,
            infeasible_p: 0.0,
        }
    }
}

impl CurveSimulator {
    /// The study config for this workload (with median early stopping on).
    pub fn study_config(&self) -> StudyConfig {
        let mut c = StudyConfig::new("curve-sim");
        c.search_space
            .add_float("learning_rate", 1e-4, 1e-1, ScaleType::Log)
            .add_int("num_layers", 1, 8);
        c.search_space.add_categorical("optimizer", vec!["sgd", "adam", "rmsprop"]);
        c.add_metric(MetricInformation::maximize("accuracy").with_range(0.0, 1.0));
        c.stopping = StoppingConfig {
            kind: StoppingKind::Median,
            min_trials: 4,
            confidence: 1.0,
        };
        c
    }

    /// The asymptotic accuracy of a configuration (noise-free).
    pub fn plateau(&self, params: &ParameterDict) -> f64 {
        let lr = params.get_f64("learning_rate").unwrap_or(1e-3);
        let layers = params.get_i64("num_layers").unwrap_or(4) as f64;
        let opt_bonus = match params.get_str("optimizer") {
            Some("adam") => 0.05,
            Some("rmsprop") => 0.02,
            _ => 0.0,
        };
        let lr_term = 1.0 - 0.25 * (lr.log10() + 2.0).powi(2); // peak at 1e-2
        let layer_term = 1.0 - 0.02 * (layers - 3.0).powi(2);
        (0.55 * lr_term + 0.35 * layer_term + opt_bonus).clamp(0.05, 0.99)
    }

    /// Curve speed: poorly tuned configs also converge slower.
    fn tau(&self, params: &ParameterDict) -> f64 {
        let lr = params.get_f64("learning_rate").unwrap_or(1e-3);
        3.0 + (lr.log10() + 2.0).abs() * 2.0
    }

    /// Accuracy at `step`, with simulated noise.
    pub fn accuracy_at(&self, params: &ParameterDict, step: i64, rng: &mut Pcg32) -> f64 {
        let plateau = self.plateau(params);
        let tau = self.tau(params);
        let clean = plateau * (1.0 - (-(step as f64) / tau).exp());
        (clean + rng.normal() * self.noise_std).clamp(0.0, 1.0)
    }

    /// Whether a freshly suggested config is fundamentally broken.
    pub fn is_infeasible(&self, params: &ParameterDict, rng: &mut Pcg32) -> bool {
        let _ = params;
        rng.bool_with(self.infeasible_p)
    }

    /// Whether this step hits a transient failure (caller should retry).
    pub fn transient_failure(&self, rng: &mut Pcg32) -> bool {
        rng.bool_with(self.transient_failure_p)
    }

    /// Produce a measurement for one step.
    pub fn measure(&self, params: &ParameterDict, step: i64, rng: &mut Pcg32) -> Measurement {
        Measurement::new(step)
            .with_metric("accuracy", self.accuracy_at(params, step, rng))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(lr: f64, layers: i64, opt: &str) -> ParameterDict {
        let mut p = ParameterDict::new();
        p.set("learning_rate", lr).set("num_layers", layers).set("optimizer", opt);
        p
    }

    #[test]
    fn optimum_is_at_expected_config() {
        let sim = CurveSimulator::default();
        let best = sim.plateau(&params(1e-2, 3, "adam"));
        assert!(best > sim.plateau(&params(1e-4, 3, "adam")));
        assert!(best > sim.plateau(&params(1e-2, 8, "adam")));
        assert!(best > sim.plateau(&params(1e-2, 3, "sgd")));
        assert!((0.0..=1.0).contains(&best));
    }

    #[test]
    fn curves_saturate_monotonically_without_noise() {
        let sim = CurveSimulator {
            noise_std: 0.0,
            ..Default::default()
        };
        let p = params(1e-2, 3, "adam");
        let mut rng = Pcg32::seeded(1);
        let accs: Vec<f64> = (1..=20).map(|s| sim.accuracy_at(&p, s, &mut rng)).collect();
        for w in accs.windows(2) {
            assert!(w[1] >= w[0]);
        }
        assert!((accs[19] - sim.plateau(&p)).abs() < 0.01);
    }

    #[test]
    fn config_valid_and_failures_respect_probabilities() {
        let sim = CurveSimulator {
            infeasible_p: 0.3,
            transient_failure_p: 0.2,
            ..Default::default()
        };
        sim.study_config().validate().unwrap();
        let mut rng = Pcg32::seeded(2);
        let p = params(1e-2, 3, "adam");
        let inf = (0..2000).filter(|_| sim.is_infeasible(&p, &mut rng)).count();
        assert!((500..=700).contains(&inf), "infeasible count {inf}");
        let tf = (0..2000).filter(|_| sim.transient_failure(&mut rng)).count();
        assert!((320..=480).contains(&tf), "transient count {tf}");
    }
}
