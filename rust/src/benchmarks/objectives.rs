//! Synthetic blackbox objectives: classic single-objective test functions
//! and the ZDT bi-objective family.

use crate::pyvizier::{MetricInformation, ParameterDict, SearchSpace, StudyConfig};
use crate::wire::messages::ScaleType;

/// A synthetic objective with a known search space and optimum.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    /// Sum of squares; optimum 0 at origin. Any dimension.
    Sphere,
    /// Classic banana valley; optimum 0 at (1, ..., 1).
    Rosenbrock,
    /// Highly multimodal; optimum 0 at origin.
    Rastrigin,
    /// 2-D with three global minima at ~0.3979.
    Branin,
    /// 6-D; optimum ~-3.3224.
    Hartmann6,
    /// Bi-objective trade-off (convex front).
    Zdt1,
    /// Bi-objective trade-off (concave front).
    Zdt2,
}

impl Objective {
    pub fn name(&self) -> &'static str {
        match self {
            Objective::Sphere => "sphere",
            Objective::Rosenbrock => "rosenbrock",
            Objective::Rastrigin => "rastrigin",
            Objective::Branin => "branin",
            Objective::Hartmann6 => "hartmann6",
            Objective::Zdt1 => "zdt1",
            Objective::Zdt2 => "zdt2",
        }
    }

    pub fn is_multiobjective(&self) -> bool {
        matches!(self, Objective::Zdt1 | Objective::Zdt2)
    }

    /// Dimensionality (fixed for Branin/Hartmann6; `d` for the rest).
    pub fn dims(&self, d: usize) -> usize {
        match self {
            Objective::Branin => 2,
            Objective::Hartmann6 => 6,
            _ => d,
        }
    }

    /// Known optimum of the single objective (None for multi-objective).
    pub fn optimum(&self) -> Option<f64> {
        match self {
            Objective::Sphere | Objective::Rosenbrock | Objective::Rastrigin => Some(0.0),
            Objective::Branin => Some(0.397887),
            Objective::Hartmann6 => Some(-3.32237),
            _ => None,
        }
    }

    /// Build the study config (search space + metrics) for this objective.
    pub fn study_config(&self, d: usize) -> StudyConfig {
        let mut config = StudyConfig::new(self.name());
        let dims = self.dims(d);
        match self {
            Objective::Branin => {
                config.search_space.add_float("x0", -5.0, 10.0, ScaleType::Linear);
                config.search_space.add_float("x1", 0.0, 15.0, ScaleType::Linear);
            }
            Objective::Zdt1 | Objective::Zdt2 => {
                for i in 0..dims {
                    config.search_space.add_float(&format!("x{i}"), 0.0, 1.0, ScaleType::Linear);
                }
            }
            Objective::Hartmann6 => {
                for i in 0..6 {
                    config.search_space.add_float(&format!("x{i}"), 0.0, 1.0, ScaleType::Linear);
                }
            }
            _ => {
                for i in 0..dims {
                    config.search_space.add_float(&format!("x{i}"), -5.0, 5.0, ScaleType::Linear);
                }
            }
        }
        if self.is_multiobjective() {
            config.add_metric(MetricInformation::minimize("f1"));
            config.add_metric(MetricInformation::minimize("f2"));
        } else {
            config.add_metric(MetricInformation::minimize("value"));
        }
        config
    }

    fn xs(&self, params: &ParameterDict, d: usize) -> Vec<f64> {
        (0..self.dims(d))
            .map(|i| params.get_f64(&format!("x{i}")).unwrap_or(0.0))
            .collect()
    }

    /// Evaluate: returns the metric map for a measurement.
    pub fn evaluate(&self, params: &ParameterDict, d: usize) -> Vec<(String, f64)> {
        let x = self.xs(params, d);
        match self {
            Objective::Sphere => {
                vec![("value".into(), x.iter().map(|v| v * v).sum())]
            }
            Objective::Rosenbrock => {
                let v = x
                    .windows(2)
                    .map(|w| 100.0 * (w[1] - w[0] * w[0]).powi(2) + (1.0 - w[0]).powi(2))
                    .sum();
                vec![("value".into(), v)]
            }
            Objective::Rastrigin => {
                let v = 10.0 * x.len() as f64
                    + x.iter()
                        .map(|v| v * v - 10.0 * (2.0 * std::f64::consts::PI * v).cos())
                        .sum::<f64>();
                vec![("value".into(), v)]
            }
            Objective::Branin => {
                let (x1, x2) = (x[0], x[1]);
                let b = 5.1 / (4.0 * std::f64::consts::PI.powi(2));
                let c = 5.0 / std::f64::consts::PI;
                let t = 1.0 / (8.0 * std::f64::consts::PI);
                let v = (x2 - b * x1 * x1 + c * x1 - 6.0).powi(2)
                    + 10.0 * (1.0 - t) * x1.cos()
                    + 10.0;
                vec![("value".into(), v)]
            }
            Objective::Hartmann6 => {
                const A: [[f64; 6]; 4] = [
                    [10.0, 3.0, 17.0, 3.5, 1.7, 8.0],
                    [0.05, 10.0, 17.0, 0.1, 8.0, 14.0],
                    [3.0, 3.5, 1.7, 10.0, 17.0, 8.0],
                    [17.0, 8.0, 0.05, 10.0, 0.1, 14.0],
                ];
                const P: [[f64; 6]; 4] = [
                    [0.1312, 0.1696, 0.5569, 0.0124, 0.8283, 0.5886],
                    [0.2329, 0.4135, 0.8307, 0.3736, 0.1004, 0.9991],
                    [0.2348, 0.1451, 0.3522, 0.2883, 0.3047, 0.6650],
                    [0.4047, 0.8828, 0.8732, 0.5743, 0.1091, 0.0381],
                ];
                const ALPHA: [f64; 4] = [1.0, 1.2, 3.0, 3.2];
                let mut v = 0.0;
                for i in 0..4 {
                    let inner: f64 = (0..6).map(|j| A[i][j] * (x[j] - P[i][j]).powi(2)).sum();
                    v -= ALPHA[i] * (-inner).exp();
                }
                vec![("value".into(), v)]
            }
            Objective::Zdt1 => {
                let f1 = x[0];
                let g = 1.0 + 9.0 * x[1..].iter().sum::<f64>() / (x.len() - 1).max(1) as f64;
                let f2 = g * (1.0 - (f1 / g).sqrt());
                vec![("f1".into(), f1), ("f2".into(), f2)]
            }
            Objective::Zdt2 => {
                let f1 = x[0];
                let g = 1.0 + 9.0 * x[1..].iter().sum::<f64>() / (x.len() - 1).max(1) as f64;
                let f2 = g * (1.0 - (f1 / g).powi(2));
                vec![("f1".into(), f1), ("f2".into(), f2)]
            }
        }
    }
}

/// All single-objective functions (the sweep set for C-CONV).
pub const SINGLE_OBJECTIVE: [Objective; 5] = [
    Objective::Sphere,
    Objective::Rosenbrock,
    Objective::Rastrigin,
    Objective::Branin,
    Objective::Hartmann6,
];

/// Require a specific search space to build an evaluator closure.
pub fn evaluator(
    obj: Objective,
    d: usize,
) -> impl Fn(&ParameterDict) -> Vec<(String, f64)> + Send + Sync + Clone {
    move |params| obj.evaluate(params, d)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn optima_are_achieved_at_known_points() {
        let mut p = ParameterDict::new();
        for i in 0..4 {
            p.set(format!("x{i}"), 0.0);
        }
        assert_eq!(Objective::Sphere.evaluate(&p, 4)[0].1, 0.0);
        let rast = Objective::Rastrigin.evaluate(&p, 4)[0].1;
        assert!(rast.abs() < 1e-9, "rastrigin at origin = {rast}");

        let mut p = ParameterDict::new();
        for i in 0..4 {
            p.set(format!("x{i}"), 1.0);
        }
        assert_eq!(Objective::Rosenbrock.evaluate(&p, 4)[0].1, 0.0);

        // Branin minimum at (pi, 2.275).
        let mut p = ParameterDict::new();
        p.set("x0", std::f64::consts::PI).set("x1", 2.275);
        let v = Objective::Branin.evaluate(&p, 2)[0].1;
        assert!((v - 0.397887).abs() < 1e-3, "branin {v}");

        // Hartmann6 minimum.
        let xopt = [0.20169, 0.150011, 0.476874, 0.275332, 0.311652, 0.6573];
        let mut p = ParameterDict::new();
        for (i, v) in xopt.iter().enumerate() {
            p.set(format!("x{i}"), *v);
        }
        let v = Objective::Hartmann6.evaluate(&p, 6)[0].1;
        assert!((v - (-3.32237)).abs() < 1e-3, "hartmann6 {v}");
    }

    #[test]
    fn configs_are_valid_and_samples_evaluate() {
        let mut rng = Pcg32::seeded(1);
        for obj in [
            Objective::Sphere,
            Objective::Rosenbrock,
            Objective::Rastrigin,
            Objective::Branin,
            Objective::Hartmann6,
            Objective::Zdt1,
            Objective::Zdt2,
        ] {
            let config = obj.study_config(4);
            config.validate().unwrap();
            for _ in 0..20 {
                let p = config.search_space.sample(&mut rng);
                let metrics = obj.evaluate(&p, 4);
                assert_eq!(metrics.len(), config.metrics.len());
                for (_, v) in metrics {
                    assert!(v.is_finite());
                }
            }
        }
    }

    #[test]
    fn zdt1_front_shape() {
        // On the Pareto front (x1..=0), f2 = 1 - sqrt(f1).
        let mut p = ParameterDict::new();
        p.set("x0", 0.25);
        for i in 1..4 {
            p.set(format!("x{i}"), 0.0);
        }
        let m = Objective::Zdt1.evaluate(&p, 4);
        assert!((m[1].1 - (1.0 - 0.5)).abs() < 1e-9);
    }
}
