//! `vizier-lint`: repo-specific invariant checker, run as a required CI
//! step (`cargo run --release --bin vizier-lint`).
//!
//! Rules (see `rust/docs/INVARIANTS.md` for the rationale behind each):
//!
//! - `safety-comment` — every `unsafe` block carries a `// SAFETY:`
//!   comment on the same line or the comment block directly above it.
//! - `ffi-errno` — in the FFI modules (`util/netpoll.rs`,
//!   `testing/procfs.rs`), a raw libc call may not silently discard its
//!   return value: bind it, test it, or discard explicitly (`let _ =`).
//! - `std-sync` — `std::sync::{Mutex, RwLock, Condvar}` are banned
//!   outside `util/sync.rs`; everything else goes through the lockdep
//!   shim so lock-order checking sees every acquisition.
//! - `no-unwrap` — no `.unwrap()` / `.expect(` on the service and
//!   datastore request paths (non-test code under `service/` and
//!   `datastore/`): a poisoned panic there kills a worker serving real
//!   traffic. Tests (`#[cfg(test)]` modules) are exempt.
//! - `lock-rank` — every `Mutex::new(` / `RwLock::new(` outside
//!   `util/sync.rs` names a registered `classes::` rank, so no lock can
//!   be created outside the declared hierarchy.
//! - `shard-map-access` — the datastore's shard maps (`.shards`, and
//!   study/trial/operation maps reached through a lock guard) may not
//!   be walked directly outside `datastore/`: readers go through the
//!   snapshot accessors (`Datastore` trait reads / `shard_image`) so
//!   the copy-on-write read protocol — and its metrics — see every
//!   access.
//! - `doc-drift` — every `--flag` declared in `main.rs` and every
//!   `OSSVIZIER_*` environment variable read anywhere in the tree must
//!   appear in `rust/docs/OPERATIONS.md`. Knobs that exist but are not
//!   in the operator manual rot silently; this rule makes the manual a
//!   compile-time-adjacent artifact. (Cross-file: the violation is
//!   reported at the declaring/reading line.)
//!
//! A violation that is genuinely intended is silenced with
//! `// lint: allow(<rule>)` on the same line or the line directly above.
//!
//! The scanner is deliberately line-based (no syntax tree): it strips
//! string/char literals and `//` comments per line, which is exact
//! enough for this codebase and keeps the tool dependency-free.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

#[derive(Debug, Clone, PartialEq, Eq)]
struct Violation {
    file: String,
    line: usize,
    rule: &'static str,
    msg: String,
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let root = match args.as_slice() {
        [] => default_src_root(),
        [r] => PathBuf::from(r),
        _ => {
            eprintln!("usage: vizier-lint [SRC_ROOT]");
            return ExitCode::from(2);
        }
    };
    if !root.is_dir() {
        eprintln!("vizier-lint: source root {} is not a directory", root.display());
        return ExitCode::from(2);
    }
    let violations = match lint_tree(&root) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("vizier-lint: {e}");
            return ExitCode::from(2);
        }
    };
    for v in &violations {
        println!("{}:{}: [{}] {}", v.file, v.line, v.rule, v.msg);
    }
    if violations.is_empty() {
        println!("vizier-lint: clean");
        ExitCode::SUCCESS
    } else {
        println!("vizier-lint: {} violation(s)", violations.len());
        ExitCode::FAILURE
    }
}

/// `<manifest dir>/src` when run under cargo, else `./src`.
fn default_src_root() -> PathBuf {
    match std::env::var("CARGO_MANIFEST_DIR") {
        Ok(m) => PathBuf::from(m).join("src"),
        Err(_) => PathBuf::from("src"),
    }
}

fn lint_tree(root: &Path) -> std::io::Result<Vec<Violation>> {
    let mut paths = Vec::new();
    walk(root, &mut paths)?;
    paths.sort();
    let mut files = Vec::new();
    for path in paths {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(&path)
            .to_string_lossy()
            .replace('\\', "/");
        let text = std::fs::read_to_string(&path)?;
        files.push((rel, text));
    }
    let mut out = Vec::new();
    for (rel, text) in &files {
        out.extend(lint_file(rel, text));
    }
    // doc-drift needs the operator manual, which lives next to src/.
    let ops_doc = root
        .parent()
        .map(|p| p.join("docs").join("OPERATIONS.md"))
        .and_then(|p| std::fs::read_to_string(p).ok());
    out.extend(doc_drift(&files, ops_doc.as_deref()));
    Ok(out)
}

/// Cross-file `doc-drift` pass: collect every CLI flag declared in
/// `main.rs` (an `OptSpec` `name: "..."` field) and every `OSSVIZIER_*`
/// environment read in the tree, and require each to appear in
/// `docs/OPERATIONS.md` (`--<flag>` for flags, the bare variable name
/// for env vars). Test modules are exempt — tests read knobs they do
/// not own. `ops_doc` is `None` when the manual itself is missing, in
/// which case every requirement fails (the fix is to write the manual).
fn doc_drift(files: &[(String, String)], ops_doc: Option<&str>) -> Vec<Violation> {
    let doc = ops_doc.unwrap_or("");
    let mut out = Vec::new();
    for (rel, text) in files {
        let lines: Vec<Line> = text.lines().map(split_line).collect();
        let test_lines = test_mod_lines(&lines);
        for (i, line) in lines.iter().enumerate() {
            if test_lines[i] || allowed(&lines, i, "doc-drift") {
                continue;
            }
            if rel == "main.rs" {
                if let Some(flag) = optspec_flag_name(line.raw) {
                    if !doc.contains(&format!("--{flag}")) {
                        out.push(Violation {
                            file: rel.clone(),
                            line: i + 1,
                            rule: "doc-drift",
                            msg: format!("flag --{flag} is not documented in docs/OPERATIONS.md"),
                        });
                    }
                }
            }
            // Env reads scan the raw line: the variable name lives in a
            // string literal, which the sanitizer blanks out of `code`.
            if line.raw.contains("env::var") {
                if let Some(var) = ossvizier_env_name(line.raw) {
                    if !doc.contains(&var) {
                        out.push(Violation {
                            file: rel.clone(),
                            line: i + 1,
                            rule: "doc-drift",
                            msg: format!("{var} is not documented in docs/OPERATIONS.md"),
                        });
                    }
                }
            }
        }
    }
    out
}

/// The flag name from an `OptSpec { name: "...", ... }` line, if any.
fn optspec_flag_name(raw: &str) -> Option<String> {
    let after = &raw[raw.find("name: \"")? + "name: \"".len()..];
    let end = after.find('"')?;
    let name = &after[..end];
    (!name.is_empty()).then(|| name.to_string())
}

/// The `OSSVIZIER_*` identifier on the line, if any.
fn ossvizier_env_name(raw: &str) -> Option<String> {
    let start = raw.find("OSSVIZIER_")?;
    let name: String = raw[start..]
        .chars()
        .take_while(|c| c.is_ascii_uppercase() || c.is_ascii_digit() || *c == '_')
        .collect();
    (name.len() > "OSSVIZIER_".len()).then_some(name)
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            walk(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// One source line, pre-split into code and comment.
struct Line<'a> {
    raw: &'a str,
    /// Code with string/char-literal contents blanked and the `//`
    /// comment removed.
    code: String,
    /// The `//` comment text, if any (everything after the marker).
    comment: Option<String>,
}

fn lint_file(rel: &str, text: &str) -> Vec<Violation> {
    let lines: Vec<Line> = text.lines().map(split_line).collect();
    let test_lines = test_mod_lines(&lines);
    let ffi_names = if is_ffi_module(rel) {
        extern_fn_names(&lines)
    } else {
        Vec::new()
    };

    let mut out = Vec::new();
    for (i, line) in lines.iter().enumerate() {
        let lineno = i + 1;
        let mut report = |rule: &'static str, msg: String| {
            if !allowed(&lines, i, rule) {
                out.push(Violation { file: rel.to_string(), line: lineno, rule, msg });
            }
        };

        // safety-comment: an `unsafe` token needs a SAFETY comment here
        // or in the comment block directly above.
        if has_word(&line.code, "unsafe") && !safety_documented(&lines, i) {
            report(
                "safety-comment",
                "unsafe block without a `// SAFETY:` comment".to_string(),
            );
        }

        // ffi-errno: a bare FFI call statement silently drops the result.
        if let Some(name) = bare_ffi_call(&line.code, &ffi_names) {
            report(
                "ffi-errno",
                format!("result of `{name}(...)` dropped; bind it, test it, or `let _ =` it"),
            );
        }

        // std-sync: raw std locks outside the lockdep shim.
        if rel != "util/sync.rs" && raw_std_lock(&line.code) {
            report(
                "std-sync",
                "raw std::sync lock; use crate::util::sync so lockdep sees it".to_string(),
            );
        }

        // no-unwrap: request paths must propagate errors.
        if (rel.starts_with("service/") || rel.starts_with("datastore/"))
            && !test_lines[i]
            && (line.code.contains(".unwrap()") || line.code.contains(".expect("))
        {
            report(
                "no-unwrap",
                "unwrap/expect on a request path; propagate the error".to_string(),
            );
        }

        // lock-rank: lock construction must name a registered class.
        if rel != "util/sync.rs"
            && (line.code.contains("Mutex::new(") || line.code.contains("RwLock::new("))
        {
            let window: String = lines[i..(i + 3).min(lines.len())]
                .iter()
                .map(|l| l.code.as_str())
                .collect::<Vec<_>>()
                .join(" ");
            if !window.contains("classes::") {
                report(
                    "lock-rank",
                    "lock constructed without a classes:: rank registration".to_string(),
                );
            }
        }

        // shard-map-access: datastore internals stay behind the
        // snapshot accessors outside datastore/.
        if !rel.starts_with("datastore/") && shard_map_access(&line.code) {
            report(
                "shard-map-access",
                "direct shard-map access; go through the datastore snapshot accessors"
                    .to_string(),
            );
        }
    }
    out
}

/// Direct reach into the datastore's sharded maps: the shard vector
/// itself, or a study/trial/operation map read through a lock guard
/// (`…read().studies`-style chains). Legal accesses go through the
/// `Datastore` trait or the `shard_image` snapshot accessor, which is
/// what keeps the copy-on-write read metrics truthful.
fn shard_map_access(code: &str) -> bool {
    const NEEDLES: [&str; 8] = [
        ".shards[",
        ".shards.",
        "read().studies",
        "read().trials",
        "read().operations",
        "write().studies",
        "write().trials",
        "write().operations",
    ];
    NEEDLES.iter().any(|n| code.contains(n))
}

/// The two modules that declare raw libc bindings.
fn is_ffi_module(rel: &str) -> bool {
    rel == "util/netpoll.rs" || rel == "testing/procfs.rs"
}

/// `// lint: allow(<rule>)` on the same line or the line directly above.
fn allowed(lines: &[Line], i: usize, rule: &str) -> bool {
    let needle = format!("lint: allow({rule})");
    let here = lines[i].comment.as_deref().unwrap_or("").contains(&needle);
    let above = i > 0 && lines[i - 1].comment.as_deref().unwrap_or("").contains(&needle);
    here || above
}

/// SAFETY on the same line, or in the contiguous run of comment /
/// attribute lines directly above.
fn safety_documented(lines: &[Line], i: usize) -> bool {
    if lines[i].comment.as_deref().unwrap_or("").contains("SAFETY:") {
        return true;
    }
    let mut j = i;
    while j > 0 {
        j -= 1;
        let l = &lines[j];
        let trimmed = l.raw.trim_start();
        let comment_only = trimmed.starts_with("//");
        let attr_only = trimmed.starts_with("#[") || trimmed.starts_with("#![");
        if comment_only {
            if l.comment.as_deref().unwrap_or("").contains("SAFETY:") {
                return true;
            }
        } else if !attr_only {
            return false;
        }
    }
    false
}

/// Names declared in `extern "C" { ... }` blocks.
fn extern_fn_names(lines: &[Line]) -> Vec<String> {
    let mut names = Vec::new();
    let mut depth: i32 = -1; // -1: outside an extern block
    for line in lines {
        let code = line.code.as_str();
        if depth < 0 {
            // The sanitizer blanks string contents, so `extern "C"`
            // arrives here as `extern ""`.
            if code.contains("extern \"") && code.contains('{') {
                depth = 0;
            }
            continue;
        }
        if let Some(rest) = code.trim_start().strip_prefix("fn ") {
            if let Some(open) = rest.find('(') {
                let name = rest[..open].trim();
                if !name.is_empty() && name.chars().all(|c| c.is_alphanumeric() || c == '_') {
                    names.push(name.to_string());
                }
            }
        }
        depth += code.matches('{').count() as i32;
        depth -= code.matches('}').count() as i32;
        if depth < 0 {
            depth = -1; // closed the extern block
        }
    }
    names
}

/// A statement that calls an FFI function and throws the result away:
/// after stripping a leading `unsafe {`, the line *starts* with the call.
fn bare_ffi_call<'n>(code: &str, names: &'n [String]) -> Option<&'n str> {
    let mut s = code.trim_start();
    if let Some(rest) = s.strip_prefix("unsafe") {
        s = rest.trim_start().strip_prefix('{').unwrap_or(rest).trim_start();
    }
    for name in names {
        if let Some(rest) = s.strip_prefix(name.as_str()) {
            if rest.starts_with('(') {
                return Some(name);
            }
        }
    }
    None
}

/// True per line when it falls inside a `#[cfg(test)] mod` body.
fn test_mod_lines(lines: &[Line]) -> Vec<bool> {
    let mut flags = vec![false; lines.len()];
    let mut depth: i32 = 0;
    let mut pending_attr = false; // saw #[cfg(test)], waiting for the mod
    let mut skip_until: Option<i32> = None; // depth at which the test mod ends
    for (i, line) in lines.iter().enumerate() {
        let code = line.code.as_str();
        if skip_until.is_none() {
            if code.contains("#[cfg(test)]") {
                pending_attr = true;
            } else if pending_attr {
                let t = code.trim_start();
                if t.starts_with("mod ") || t.starts_with("pub mod ") {
                    skip_until = Some(depth);
                }
                if !t.is_empty() && !t.starts_with("#[") {
                    pending_attr = false;
                }
            }
        }
        if skip_until.is_some() {
            flags[i] = true;
        }
        depth += code.matches('{').count() as i32;
        depth -= code.matches('}').count() as i32;
        if let Some(d) = skip_until {
            if depth <= d {
                skip_until = None;
            }
        }
    }
    flags
}

/// A banned lock type reached through `std::sync`: either directly
/// (`std::sync::Mutex`) or via an import list (`use std::sync::{...}`
/// naming Mutex/RwLock/Condvar). `std::sync::Arc<Mutex<..>>` — the shim
/// Mutex inside a std Arc — is legal and must not match.
fn raw_std_lock(code: &str) -> bool {
    const BAD: [&str; 3] = ["Mutex", "RwLock", "Condvar"];
    const PREFIX: &str = "std::sync::";
    let mut from = 0;
    while let Some(p) = code[from..].find(PREFIX) {
        let after = &code[from + p + PREFIX.len()..];
        if let Some(inner) = after.strip_prefix('{') {
            let list = &inner[..inner.find('}').unwrap_or(inner.len())];
            if BAD.iter().any(|w| has_word(list, w)) {
                return true;
            }
        } else if BAD.iter().any(|w| {
            after
                .strip_prefix(w)
                .is_some_and(|rest| rest.is_empty() || !is_ident(rest.as_bytes()[0]))
        }) {
            return true;
        }
        from += p + PREFIX.len();
    }
    false
}

/// `word` present in `code` with identifier-character boundaries (so
/// `unsafe_op_in_unsafe_fn` does not count as `unsafe`).
fn has_word(code: &str, word: &str) -> bool {
    let bytes = code.as_bytes();
    let mut from = 0;
    while let Some(pos) = code[from..].find(word) {
        let start = from + pos;
        let end = start + word.len();
        let ok_before = start == 0 || !is_ident(bytes[start - 1]);
        let ok_after = end == bytes.len() || !is_ident(bytes[end]);
        if ok_before && ok_after {
            return true;
        }
        from = end;
    }
    false
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Split a raw line into sanitized code (string/char contents blanked,
/// comment removed) and the `//` comment text.
fn split_line(raw: &str) -> Line<'_> {
    let mut code = String::with_capacity(raw.len());
    let mut comment = None;
    let chars: Vec<char> = raw.chars().collect();
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        if c == '/' && i + 1 < chars.len() && chars[i + 1] == '/' {
            comment = Some(chars[i + 2..].iter().collect());
            break;
        }
        if c == '"' {
            // String literal: blank the contents, keep the quotes.
            code.push('"');
            i += 1;
            while i < chars.len() {
                if chars[i] == '\\' {
                    i += 2;
                    continue;
                }
                if chars[i] == '"' {
                    break;
                }
                i += 1;
            }
            code.push('"');
            i += 1; // past the closing quote (or the end)
            continue;
        }
        if c == '\'' {
            // Char literal ('x', '\n', '\'') vs lifetime ('a in types).
            let is_char_lit = match chars.get(i + 1) {
                Some('\\') => true,
                Some(_) => chars.get(i + 2) == Some(&'\''),
                None => false,
            };
            if is_char_lit {
                code.push_str("' '");
                i += 1;
                if chars.get(i) == Some(&'\\') {
                    i += 2;
                } else {
                    i += 1;
                }
                i += 1; // closing quote
                continue;
            }
        }
        code.push(c);
        i += 1;
    }
    Line { raw, code, comment }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules(rel: &str, text: &str) -> Vec<&'static str> {
        lint_file(rel, text).into_iter().map(|v| v.rule).collect()
    }

    #[test]
    fn clean_file_has_no_violations() {
        let src = r#"
            use crate::util::sync::{classes, Mutex};
            fn f() {
                let m = Mutex::new(&classes::SVC_COALESCE, 0u32);
                let _g = m.lock();
            }
        "#;
        assert!(rules("service/api.rs", src).is_empty());
    }

    #[test]
    fn undocumented_unsafe_is_flagged() {
        let src = "fn f() { let x = unsafe { g() }; }";
        assert_eq!(rules("util/x.rs", src), vec!["safety-comment"]);
    }

    #[test]
    fn safety_comment_same_line_or_above_passes() {
        let same = "fn f() { let x = unsafe { g() }; } // SAFETY: g is fine";
        assert!(rules("util/x.rs", same).is_empty());
        let above = "// SAFETY: g has no preconditions\n// (more detail)\nfn f() { let x = unsafe { g() }; }";
        assert!(rules("util/x.rs", above).is_empty());
        let gap = "// SAFETY: too far away\nfn unrelated() {}\nfn f() { let x = unsafe { g() }; }";
        assert_eq!(rules("util/x.rs", gap), vec!["safety-comment"]);
    }

    #[test]
    fn deny_attr_is_not_an_unsafe_block() {
        let src = "#![deny(unsafe_op_in_unsafe_fn)]";
        assert!(rules("lib.rs", src).is_empty());
    }

    #[test]
    fn bare_ffi_call_is_flagged_in_ffi_modules_only() {
        let src = "extern \"C\" {\n    fn close(fd: i32) -> i32;\n}\nfn f(fd: i32) {\n    // SAFETY: fd is owned\n    unsafe {\n        close(fd);\n    }\n}";
        assert_eq!(rules("util/netpoll.rs", src), vec!["ffi-errno"]);
        // Same text elsewhere: the file declares no watched FFI module.
        assert!(rules("util/other.rs", src).is_empty());
    }

    #[test]
    fn bound_tested_or_discarded_ffi_calls_pass() {
        let src = "extern \"C\" {\n    fn close(fd: i32) -> i32;\n    fn pipe(p: *mut i32) -> i32;\n}\nfn f(fd: i32, p: *mut i32) {\n    // SAFETY: fd owned; result discarded deliberately\n    let _ = unsafe { close(fd) };\n    // SAFETY: p valid for two fds\n    if unsafe { pipe(p) } != 0 {}\n}";
        assert!(rules("util/netpoll.rs", src).is_empty());
    }

    #[test]
    fn raw_std_locks_are_flagged_outside_the_shim() {
        assert_eq!(
            rules("service/api.rs", "use std::sync::Mutex;"),
            vec!["std-sync"]
        );
        assert_eq!(
            rules("datastore/x.rs", "use std::sync::{Arc, Condvar};"),
            vec!["std-sync"]
        );
        // mpsc/Arc/atomics from std::sync stay legal.
        assert!(rules("service/api.rs", "use std::sync::{mpsc, Arc};").is_empty());
        assert!(rules("util/sync.rs", "use std::sync::Mutex as StdMutex;").is_empty());
        // A std Arc holding the *shim* Mutex is legal...
        let arc_of_shim = "methods: Mutex<BTreeMap<String, std::sync::Arc<Histogram>>>,";
        assert!(rules("service/metrics.rs", arc_of_shim).is_empty());
        // ...but the std lock reached through the path is not.
        assert_eq!(
            rules("service/api.rs", "let m = std::sync::Mutex::new(0); // lint: allow(lock-rank)"),
            vec!["std-sync"]
        );
    }

    #[test]
    fn unwrap_on_request_paths_is_flagged_but_tests_are_exempt() {
        let src = "fn f() { g().unwrap(); }";
        assert_eq!(rules("service/api.rs", src), vec!["no-unwrap"]);
        assert_eq!(rules("datastore/wal.rs", "fn f() { g().expect(\"x\"); }"), vec!["no-unwrap"]);
        // Not a request path:
        assert!(rules("util/x.rs", src).is_empty());
        // unwrap_or_else and friends are fine:
        assert!(rules("service/api.rs", "fn f() { g().unwrap_or_default(); }").is_empty());
        // Test modules are exempt:
        let test_mod = "fn f() {}\n#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() { g().unwrap(); }\n}\n";
        assert!(rules("service/api.rs", test_mod).is_empty());
        // ...but code after the test mod closes is not:
        let after = "#[cfg(test)]\nmod tests {\n    fn t() { g().unwrap(); }\n}\nfn f() { g().unwrap(); }";
        assert_eq!(rules("service/api.rs", after), vec!["no-unwrap"]);
    }

    #[test]
    fn unregistered_lock_construction_is_flagged() {
        assert_eq!(
            rules("service/api.rs", "let m = Mutex::new(0u32);"),
            vec!["lock-rank"]
        );
        // Multiline constructor: the class may be on a following line.
        let multiline = "let m = Mutex::new(\n    &classes::SVC_COALESCE,\n    0u32,\n);";
        assert!(rules("service/api.rs", multiline).is_empty());
        assert!(rules("util/sync.rs", "let m = Mutex::new(&LOCAL_CLASS, ());").is_empty());
    }

    #[test]
    fn allow_comment_silences_a_rule() {
        let same_line = "fn f() { g().unwrap(); } // lint: allow(no-unwrap)";
        assert!(rules("service/api.rs", same_line).is_empty());
        let above = "// lint: allow(no-unwrap) — startup only\nfn f() { g().unwrap(); }";
        assert!(rules("service/api.rs", above).is_empty());
        // The wrong rule name does not silence it.
        let wrong = "fn f() { g().unwrap(); } // lint: allow(std-sync)";
        assert_eq!(rules("service/api.rs", wrong), vec!["no-unwrap"]);
    }

    #[test]
    fn shard_map_access_is_flagged_outside_datastore() {
        assert_eq!(
            rules("service/api.rs", "let n = self.ds.shards[idx].read().studies.len();"),
            vec!["shard-map-access"]
        );
        assert_eq!(
            rules("pythia/runner.rs", "for s in shard.read().trials.values() {}"),
            vec!["shard-map-access"]
        );
        // The datastore's own modules implement the accessor.
        assert!(rules(
            "datastore/memory.rs",
            "let n = self.shards[idx].read().studies.len();"
        )
        .is_empty());
        // Going through the snapshot accessor is the sanctioned path.
        assert!(rules("service/api.rs", "let img = mem.shard_image(idx);").is_empty());
        // Unrelated `.trials` fields (protos, pages) stay legal.
        assert!(rules("service/api.rs", "let ts = page.trials.len() + op.trials.len();").is_empty());
        // An intended escape is silenced like every other rule.
        let allowed =
            "let n = ds.shards[0].read().studies.len(); // lint: allow(shard-map-access)";
        assert!(rules("service/api.rs", allowed).is_empty());
    }

    #[test]
    fn string_literals_do_not_trigger_rules() {
        let src = "fn f() { let s = \"unsafe std::sync::Mutex .unwrap() Mutex::new(\"; g(s); }";
        assert!(rules("service/api.rs", src).is_empty());
    }

    #[test]
    fn doc_drift_requires_flags_and_env_vars_in_operations_md() {
        let main_src = "fn specs() -> Vec<OptSpec> {\n    vec![\n        OptSpec { name: \"wal-path\", takes_value: true, help: \"x\" },\n        OptSpec { name: \"secret-knob\", takes_value: true, help: \"x\" },\n    ]\n}\n";
        let util_src = "fn rate() -> bool {\n    std::env::var(\"OSSVIZIER_EXAMPLE\").is_ok()\n}\n";
        let files = vec![
            ("main.rs".to_string(), main_src.to_string()),
            ("util/x.rs".to_string(), util_src.to_string()),
        ];
        let doc = "## Flags\n\n`--wal-path` — the WAL.\n\n## Env\n\n`OSSVIZIER_EXAMPLE` — a knob.\n";
        // Documented flag + env var: clean.
        let v = doc_drift(&files, Some(doc));
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "doc-drift");
        assert_eq!(v[0].file, "main.rs");
        assert_eq!(v[0].line, 4);
        assert!(v[0].msg.contains("--secret-knob"), "{}", v[0].msg);
        // Missing manual: everything fails.
        assert_eq!(doc_drift(&files, None).len(), 3);
    }

    #[test]
    fn doc_drift_exempts_tests_and_allow_comments() {
        let allowed_src = "fn f() {\n    // lint: allow(doc-drift) — internal debug knob\n    std::env::var(\"OSSVIZIER_HIDDEN\").ok();\n}\n";
        let files = vec![("util/x.rs".to_string(), allowed_src.to_string())];
        assert!(doc_drift(&files, Some("")).is_empty());

        let test_src = "fn f() {}\n#[cfg(test)]\nmod tests {\n    fn t() { std::env::var(\"OSSVIZIER_TESTONLY\").ok(); }\n}\n";
        let files = vec![("util/y.rs".to_string(), test_src.to_string())];
        assert!(doc_drift(&files, Some("")).is_empty());
    }

    #[test]
    fn doc_drift_extractors() {
        assert_eq!(
            optspec_flag_name("        OptSpec { name: \"wal-sync\", takes_value: true, help: \"h\" },"),
            Some("wal-sync".to_string())
        );
        assert_eq!(optspec_flag_name("let x = 1;"), None);
        assert_eq!(
            ossvizier_env_name("    match std::env::var(\"OSSVIZIER_WAL_COMMIT\").as_deref() {"),
            Some("OSSVIZIER_WAL_COMMIT".to_string())
        );
        assert_eq!(ossvizier_env_name("std::env::var(\"PATH\")"), None);
    }

    #[test]
    fn lint_tree_walks_and_reports_paths() {
        let dir = std::env::temp_dir().join(format!(
            "vizier-lint-test-{}-{}",
            std::process::id(),
            std::thread::current().name().unwrap_or("t").len()
        ));
        let svc = dir.join("service");
        std::fs::create_dir_all(&svc).unwrap();
        std::fs::write(svc.join("bad.rs"), "fn f() { g().unwrap(); }\n").unwrap();
        std::fs::write(dir.join("ok.rs"), "fn f() {}\n").unwrap();
        let v = lint_tree(&dir).unwrap();
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].file, "service/bad.rs");
        assert_eq!(v[0].rule, "no-unwrap");
        assert_eq!(v[0].line, 1);
        std::fs::remove_dir_all(&dir).ok();
    }
}
