//! `benchdiff` — the compare-benches CI gate.
//!
//! Compares the `BENCH_*.json` artifacts a bench run just produced
//! against the committed baselines in `bench_baselines/` and prints a
//! markdown trajectory table (CI appends it to the job summary). An
//! *enforced* metric — listed in the baseline file's `"enforce"` array —
//! that regresses more than `--threshold` (default 20%) fails the run,
//! which is how the nightly soak gates on performance.
//!
//! Baselines marked `"provisional": true` are recorded but never
//! enforced: they bootstrap the trajectory before a trusted runner has
//! produced real numbers. Refresh baselines from a good run with
//! `benchdiff --update`, which writes current values into the baseline
//! directory and clears the provisional flag.
//!
//! ```text
//! benchdiff [--baseline-dir bench_baselines] [--current-dir .]
//!           [--threshold 0.20] [--advisory] [--update]
//! ```

use ossvizier::util::json::{parse, Json};
use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};
use std::process::ExitCode;

struct Args {
    baseline_dir: PathBuf,
    current_dir: PathBuf,
    threshold: f64,
    /// Report regressions without failing (PR CI; the soak enforces).
    advisory: bool,
    /// Rewrite the baselines from the current artifacts.
    update: bool,
}

fn parse_args() -> Result<Args, String> {
    let mut args = Args {
        baseline_dir: PathBuf::from("bench_baselines"),
        current_dir: PathBuf::from("."),
        threshold: 0.20,
        advisory: false,
        update: false,
    };
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        let mut value = |flag: &str| {
            it.next().ok_or_else(|| format!("{flag} needs a value"))
        };
        match a.as_str() {
            "--baseline-dir" => args.baseline_dir = PathBuf::from(value("--baseline-dir")?),
            "--current-dir" => args.current_dir = PathBuf::from(value("--current-dir")?),
            "--threshold" => {
                args.threshold = value("--threshold")?
                    .parse()
                    .map_err(|_| "--threshold needs a float".to_string())?
            }
            "--advisory" => args.advisory = true,
            "--update" => args.update = true,
            other => return Err(format!("unknown argument {other:?}")),
        }
    }
    Ok(args)
}

fn load(path: &Path) -> Result<Json, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
    parse(&text).map_err(|e| format!("{}: {e}", path.display()))
}

/// `results` array -> metric name -> ns_per_op.
fn results_map(doc: &Json) -> BTreeMap<String, f64> {
    let mut out = BTreeMap::new();
    if let Some(results) = doc.get("results").and_then(Json::as_arr) {
        for r in results {
            if let (Some(name), Some(ns)) = (
                r.get("name").and_then(Json::as_str),
                r.get("ns_per_op").and_then(Json::as_f64),
            ) {
                out.insert(name.to_string(), ns);
            }
        }
    }
    out
}

struct Row {
    bench: String,
    metric: String,
    baseline: Option<f64>,
    current: Option<f64>,
    status: String,
    failed: bool,
}

fn fmt_ns(v: Option<f64>) -> String {
    match v {
        Some(ns) if ns > 0.0 => format!("{ns:.0}"),
        Some(_) => "–".to_string(),
        None => "–".to_string(),
    }
}

fn fmt_delta(baseline: Option<f64>, current: Option<f64>) -> String {
    match (baseline, current) {
        (Some(b), Some(c)) if b > 0.0 => format!("{:+.1}%", (c - b) / b * 100.0),
        _ => "–".to_string(),
    }
}

fn write_updated_baseline(
    path: &Path,
    bench: &str,
    enforce: &BTreeSet<String>,
    current: &BTreeMap<String, f64>,
) -> Result<(), String> {
    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str(bench.to_string()));
    root.insert("provisional".to_string(), Json::Bool(false));
    root.insert(
        "enforce".to_string(),
        Json::Arr(enforce.iter().map(|n| Json::Str(n.clone())).collect()),
    );
    root.insert(
        "results".to_string(),
        Json::Arr(
            current
                .iter()
                .map(|(name, ns)| {
                    let mut o = BTreeMap::new();
                    o.insert("name".to_string(), Json::Str(name.clone()));
                    o.insert("ns_per_op".to_string(), Json::Num(*ns));
                    Json::Obj(o)
                })
                .collect(),
        ),
    );
    std::fs::write(path, Json::Obj(root).to_pretty())
        .map_err(|e| format!("{}: {e}", path.display()))
}

fn run(args: &Args) -> Result<bool, String> {
    let mut baseline_files: Vec<PathBuf> = std::fs::read_dir(&args.baseline_dir)
        .map_err(|e| format!("{}: {e}", args.baseline_dir.display()))?
        .flatten()
        .map(|e| e.path())
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with("BENCH_") && n.ends_with(".json"))
        })
        .collect();
    baseline_files.sort();
    if baseline_files.is_empty() {
        return Err(format!("no BENCH_*.json baselines in {}", args.baseline_dir.display()));
    }

    let mut rows: Vec<Row> = Vec::new();
    for bpath in &baseline_files {
        let fname = bpath.file_name().unwrap().to_string_lossy().to_string();
        let baseline = load(bpath)?;
        let fallback = fname.trim_start_matches("BENCH_").trim_end_matches(".json");
        let bench = baseline
            .get("bench")
            .and_then(Json::as_str)
            .unwrap_or(fallback)
            .to_string();
        let provisional = baseline.get("provisional").and_then(Json::as_bool).unwrap_or(false);
        let enforce: BTreeSet<String> = baseline
            .get("enforce")
            .and_then(Json::as_arr)
            .map(|a| a.iter().filter_map(|v| v.as_str().map(String::from)).collect())
            .unwrap_or_default();
        let base_map = results_map(&baseline);
        let cpath = args.current_dir.join(&fname);
        if !cpath.exists() {
            let failed = !provisional && !enforce.is_empty();
            rows.push(Row {
                bench,
                metric: "(all)".into(),
                baseline: None,
                current: None,
                status: if failed {
                    "MISSING artifact — enforced bench did not run".into()
                } else {
                    "missing artifact".into()
                },
                failed,
            });
            continue;
        }
        let cur_map = results_map(&load(&cpath)?);
        for (metric, base_ns) in &base_map {
            let cur_ns = cur_map.get(metric).copied();
            let enforced = enforce.contains(metric) && !provisional;
            let (status, failed) = match cur_ns {
                None if enforced => ("MISSING metric".to_string(), true),
                None => ("missing metric".to_string(), false),
                Some(c) => {
                    if provisional {
                        ("provisional baseline (recorded, not enforced)".to_string(), false)
                    } else if *base_ns > 0.0 && c > base_ns * (1.0 + args.threshold) {
                        if enforced {
                            (
                                format!("REGRESSION > {:.0}%", args.threshold * 100.0),
                                true,
                            )
                        } else {
                            ("regression (advisory metric)".to_string(), false)
                        }
                    } else if enforced {
                        ("ok (enforced)".to_string(), false)
                    } else {
                        ("ok".to_string(), false)
                    }
                }
            };
            rows.push(Row {
                bench: bench.clone(),
                metric: metric.clone(),
                baseline: Some(*base_ns),
                current: cur_ns,
                status,
                failed,
            });
        }
        // An enforce entry with no baseline row would otherwise never be
        // examined — e.g. a metric renamed and then `--update` dropping
        // the old row while its name lingers in the enforce array. Make
        // the dead entry loudly visible instead of silently disarming.
        for name in &enforce {
            if !base_map.contains_key(name) {
                rows.push(Row {
                    bench: bench.clone(),
                    metric: name.clone(),
                    baseline: None,
                    current: cur_map.get(name).copied(),
                    status: "MISSING baseline row for enforced metric".to_string(),
                    failed: !provisional,
                });
            }
        }
        for (metric, cur_ns) in &cur_map {
            if !base_map.contains_key(metric) {
                rows.push(Row {
                    bench: bench.clone(),
                    metric: metric.clone(),
                    baseline: None,
                    current: Some(*cur_ns),
                    status: "new (unbaselined)".to_string(),
                    failed: false,
                });
            }
        }
        if args.update {
            write_updated_baseline(bpath, &bench, &enforce, &cur_map)?;
        }
    }

    println!("## Bench trajectory\n");
    println!("| bench | metric | baseline ns/op | current ns/op | Δ | status |");
    println!("|---|---|---:|---:|---:|---|");
    for r in &rows {
        println!(
            "| {} | {} | {} | {} | {} | {} |",
            r.bench,
            r.metric,
            fmt_ns(r.baseline),
            fmt_ns(r.current),
            fmt_delta(r.baseline, r.current),
            r.status
        );
    }
    let failures: Vec<&Row> = rows.iter().filter(|r| r.failed).collect();
    println!();
    if failures.is_empty() {
        println!("no enforced regressions (threshold {:.0}%)", args.threshold * 100.0);
    } else {
        println!(
            "**{} enforced regression(s) beyond {:.0}%:**",
            failures.len(),
            args.threshold * 100.0
        );
        for r in &failures {
            println!("- {} / {}: {}", r.bench, r.metric, r.status);
        }
        if args.advisory {
            println!("\n(advisory mode: not failing this run — the nightly soak enforces)");
        }
    }
    if args.update {
        println!("\nbaselines refreshed in {}", args.baseline_dir.display());
    }
    Ok(failures.is_empty() || args.advisory)
}

fn main() -> ExitCode {
    let args = match parse_args() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("benchdiff: {e}");
            return ExitCode::from(2);
        }
    };
    match run(&args) {
        Ok(true) => ExitCode::SUCCESS,
        Ok(false) => ExitCode::FAILURE,
        Err(e) => {
            eprintln!("benchdiff: {e}");
            ExitCode::from(2)
        }
    }
}
