//! Median automated stopping (paper Appendix B.1): "a pending trial is
//! stopped if the Trial's best objective value is strictly below the median
//! 'performance' of all completed Trials reported up to the Trial's last
//! measurement", where 'performance' is the running average of reported
//! objective values.

use crate::pythia::policy::EarlyStopDecision;
use crate::pyvizier::{StudyConfig, Trial};

pub fn median_should_stop(
    config: &StudyConfig,
    trial: &Trial,
    completed: &[Trial],
) -> EarlyStopDecision {
    let metric = config.single_objective();
    let maximize = metric.goal == crate::wire::messages::MetricGoal::Maximize;

    let Some(last_step) = trial.last_step() else {
        return EarlyStopDecision::default(); // no measurements yet
    };
    if (completed.len() as u64) < config.stopping.min_trials {
        return EarlyStopDecision::default();
    }

    // Median of completed trials' running averages up to last_step.
    let mut perf: Vec<f64> = completed
        .iter()
        .filter(|t| t.is_feasible_completed())
        .filter_map(|t| t.running_average_until(&metric.name, last_step))
        .collect();
    if (perf.len() as u64) < config.stopping.min_trials {
        return EarlyStopDecision::default();
    }
    perf.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = if perf.len() % 2 == 1 {
        perf[perf.len() / 2]
    } else {
        0.5 * (perf[perf.len() / 2 - 1] + perf[perf.len() / 2])
    };

    let Some(best) = trial.best_intermediate(&metric.name, maximize) else {
        return EarlyStopDecision::default();
    };
    let below = if maximize { best < median } else { best > median };
    if below {
        EarlyStopDecision {
            trial_id: trial.id,
            should_stop: true,
            reason: format!(
                "median stopping: best {} = {best:.6} is worse than median running \
                 average {median:.6} at step {last_step}",
                metric.name
            ),
        }
    } else {
        EarlyStopDecision::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stopping::test_curves::{curve_trial, partial_trial};
    use crate::pyvizier::MetricInformation;
    use crate::wire::messages::{StoppingConfig, StoppingKind};

    fn config() -> StudyConfig {
        let mut c = StudyConfig::new("curves");
        c.add_metric(MetricInformation::maximize("acc"));
        c.stopping = StoppingConfig {
            kind: StoppingKind::Median,
            min_trials: 3,
            confidence: 1.0,
        };
        c
    }

    fn completed_pool() -> Vec<Trial> {
        // Plateaus 0.6..0.9 — median running averages well above a bad trial.
        (0..5).map(|i| curve_trial(i + 1, 0.6 + 0.075 * i as f64, 5.0, 20)).collect()
    }

    #[test]
    fn bad_curve_is_stopped() {
        let c = config();
        let bad = partial_trial(10, 0.2, 5.0, 8); // plateau far below all
        let d = median_should_stop(&c, &bad, &completed_pool());
        assert!(d.should_stop, "{}", d.reason);
        assert!(d.reason.contains("median"));
    }

    #[test]
    fn good_curve_continues() {
        let c = config();
        let good = partial_trial(10, 0.95, 5.0, 8); // above every plateau
        assert!(!median_should_stop(&c, &good, &completed_pool()).should_stop);
    }

    #[test]
    fn respects_min_trials() {
        let c = config();
        let bad = partial_trial(10, 0.1, 5.0, 8);
        let few: Vec<Trial> = completed_pool().into_iter().take(2).collect();
        assert!(!median_should_stop(&c, &bad, &few).should_stop);
    }

    #[test]
    fn no_measurements_never_stops() {
        let c = config();
        let empty = Trial::new(1, Default::default());
        assert!(!median_should_stop(&c, &empty, &completed_pool()).should_stop);
    }

    #[test]
    fn minimize_direction() {
        let mut c = config();
        c.metrics[0] = MetricInformation::minimize("acc");
        // For minimization a *high* curve is bad.
        let bad = partial_trial(10, 0.9, 2.0, 8);
        let pool: Vec<Trial> = (0..5).map(|i| curve_trial(i + 1, 0.1 + 0.02 * i as f64, 5.0, 20)).collect();
        let d = median_should_stop(&c, &bad, &pool);
        assert!(d.should_stop);
    }
}
