//! Decay-curve automated stopping (paper Appendix B.1): "a Gaussian
//! Process Regressor is built to predict the final objective value of a
//! Trial based on the already completed Trials and the intermediate
//! measurements of the current Trial. Early stopping is requested ... if
//! there is very low probability to exceed the optimal value found so far."
//!
//! Implementation: a 1-D GP over normalized step (using
//! [`crate::policies::gp_math`]) fit to the trial's partial curve,
//! extrapolated to the curve's end; the trial stops when the UCB
//! (`confidence` sigmas above the predicted final value) is still below
//! the best completed objective.

use crate::policies::gp_math::{GpParams, GpPosterior};
use crate::pythia::policy::EarlyStopDecision;
use crate::pyvizier::{StudyConfig, Trial};

pub fn decay_curve_should_stop(
    config: &StudyConfig,
    trial: &Trial,
    completed: &[Trial],
) -> EarlyStopDecision {
    let metric = config.single_objective();
    let maximize = metric.goal == crate::wire::messages::MetricGoal::Maximize;

    if (completed.iter().filter(|t| t.is_feasible_completed()).count() as u64)
        < config.stopping.min_trials
    {
        return EarlyStopDecision::default();
    }
    // Best completed objective (maximization orientation).
    let Some(best) = completed
        .iter()
        .filter_map(|t| t.final_metric(&metric.name))
        .map(|v| metric.maximization_value(v))
        .max_by(|a, b| a.partial_cmp(b).unwrap())
    else {
        return EarlyStopDecision::default();
    };

    // The horizon: the longest curve among completed trials.
    let horizon = completed
        .iter()
        .filter_map(|t| t.last_step())
        .max()
        .unwrap_or(0)
        .max(trial.last_step().unwrap_or(0));
    if horizon == 0 {
        return EarlyStopDecision::default();
    }

    // Fit a 1-D GP to this trial's partial curve (needs >= 3 points).
    let points: Vec<(f64, f64)> = trial
        .measurements
        .iter()
        .filter_map(|m| {
            m.get(&metric.name)
                .map(|v| (m.step as f64 / horizon as f64, metric.maximization_value(v)))
        })
        .collect();
    if points.len() < 3 {
        return EarlyStopDecision::default();
    }
    let x: Vec<Vec<f64>> = points.iter().map(|(s, _)| vec![*s]).collect();
    let y: Vec<f64> = points.iter().map(|(_, v)| *v).collect();
    let Ok(gp) = GpPosterior::fit(
        x,
        &y,
        GpParams {
            // Longer lengthscale: learning curves are smooth in step.
            lengthscale: 0.5,
            sigma2: 1.0,
            noise: 1e-4,
        },
    ) else {
        return EarlyStopDecision::default();
    };

    // Optimistic prediction of the final value.
    let (mu, var) = gp.predict(&[1.0]);
    let ucb = mu + config.stopping.confidence * var.sqrt();
    if ucb < best {
        EarlyStopDecision {
            trial_id: trial.id,
            should_stop: true,
            reason: format!(
                "decay-curve stopping: predicted final {} = {:.6} (+{:.2}σ = {:.6}) \
                 cannot reach best completed {:.6}",
                metric.name,
                if maximize { mu } else { -mu },
                config.stopping.confidence,
                if maximize { ucb } else { -ucb },
                if maximize { best } else { -best },
            ),
        }
    } else {
        EarlyStopDecision::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stopping::test_curves::{curve_trial, partial_trial};
    use crate::pyvizier::MetricInformation;
    use crate::wire::messages::{StoppingConfig, StoppingKind};

    fn config(confidence: f64) -> StudyConfig {
        let mut c = StudyConfig::new("curves");
        c.add_metric(MetricInformation::maximize("acc"));
        c.stopping = StoppingConfig {
            kind: StoppingKind::DecayCurve,
            min_trials: 2,
            confidence,
        };
        c
    }

    fn pool() -> Vec<Trial> {
        vec![
            curve_trial(1, 0.85, 4.0, 30),
            curve_trial(2, 0.9, 4.0, 30),
            curve_trial(3, 0.8, 4.0, 30),
        ]
    }

    #[test]
    fn hopeless_curve_is_stopped() {
        let c = config(1.64);
        // Plateaus at 0.3 — GP extrapolation stays far below best (0.9).
        let bad = partial_trial(10, 0.3, 3.0, 15);
        let d = decay_curve_should_stop(&c, &bad, &pool());
        assert!(d.should_stop, "{}", d.reason);
        assert!(d.reason.contains("decay-curve"));
    }

    #[test]
    fn promising_curve_survives() {
        let c = config(1.64);
        // Heading above 0.9.
        let good = partial_trial(10, 0.97, 4.0, 15);
        let d = decay_curve_should_stop(&c, &good, &pool());
        assert!(!d.should_stop, "{}", d.reason);
    }

    #[test]
    fn early_curve_with_few_points_continues() {
        let c = config(1.64);
        let young = partial_trial(10, 0.2, 3.0, 2); // only 2 measurements
        assert!(!decay_curve_should_stop(&c, &young, &pool()).should_stop);
    }

    #[test]
    fn higher_confidence_stops_less() {
        // With a huge confidence multiplier even a bad curve survives.
        let c = config(50.0);
        let bad = partial_trial(10, 0.3, 3.0, 15);
        assert!(!decay_curve_should_stop(&c, &bad, &pool()).should_stop);
    }

    #[test]
    fn respects_min_trials() {
        let c = config(1.64);
        let bad = partial_trial(10, 0.1, 3.0, 15);
        assert!(!decay_curve_should_stop(&c, &bad, &pool()[..1]).should_stop);
    }
}
