//! Automated/early stopping (paper Appendix B.1).
//!
//! Two rules, selectable per-study via `StudyConfig.stopping`:
//! * [`median::median_should_stop`] — stop a pending trial whose best
//!   objective so far is strictly below the median *running average* of
//!   completed trials at the same step.
//! * [`decay_curve::decay_curve_should_stop`] — fit a Gaussian-process
//!   regressor to the trial's partial curve, predict the final value, and
//!   stop if the optimistic (UCB) prediction still cannot beat the best
//!   completed trial.

pub mod decay_curve;
pub mod median;

use crate::pythia::policy::EarlyStopDecision;
use crate::pyvizier::{StudyConfig, Trial};
use crate::wire::messages::StoppingKind;

/// Apply the study's configured automated-stopping rule.
pub fn decide(config: &StudyConfig, trial: &Trial, completed: &[Trial]) -> EarlyStopDecision {
    match config.stopping.kind {
        StoppingKind::None => EarlyStopDecision::default(),
        StoppingKind::Median => median::median_should_stop(config, trial, completed),
        StoppingKind::DecayCurve => decay_curve::decay_curve_should_stop(config, trial, completed),
    }
}

#[cfg(test)]
pub(crate) mod test_curves {
    //! Shared synthetic learning-curve fixtures.
    use crate::pyvizier::{Measurement, ParameterDict, Trial, TrialState};

    /// A completed trial with accuracy curve `plateau * (1 - exp(-step/tau))`.
    pub fn curve_trial(id: u64, plateau: f64, tau: f64, steps: i64) -> Trial {
        let mut t = Trial::new(id, ParameterDict::new());
        for s in 1..=steps {
            let acc = plateau * (1.0 - (-(s as f64) / tau).exp());
            t.measurements.push(Measurement::new(s).with_metric("acc", acc));
        }
        t.state = TrialState::Completed;
        t.final_measurement = Some(
            Measurement::new(steps).with_metric("acc", plateau * (1.0 - (-(steps as f64) / tau).exp())),
        );
        t
    }

    /// Same curve but still running (no final measurement, ACTIVE).
    pub fn partial_trial(id: u64, plateau: f64, tau: f64, steps: i64) -> Trial {
        let mut t = curve_trial(id, plateau, tau, steps);
        t.state = TrialState::Active;
        t.final_measurement = None;
        t
    }
}
