//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts and executes
//! them from the Rust hot path. Python never runs at request time — the
//! artifacts are produced once by `make artifacts`
//! (python/compile/aot.py) and this module is self-contained after that.

pub mod gp_artifact;
pub mod pjrt;
pub mod registry;

pub use gp_artifact::GpArtifactBackend;
pub use pjrt::{PjrtExecutable, PjrtRuntime};
pub use registry::{ArtifactRegistry, VariantKey};
