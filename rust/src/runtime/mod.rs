//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts and executes
//! them from the Rust hot path. Python never runs at request time — the
//! artifacts are produced once by `make artifacts`
//! (python/compile/aot.py) and this module is self-contained after that.

pub mod gp_artifact;
pub mod pjrt;
pub mod registry;

pub use gp_artifact::GpArtifactBackend;
pub use pjrt::{PjrtExecutable, PjrtRuntime};
pub use registry::{ArtifactRegistry, VariantKey};

/// Runtime-layer error (artifact discovery, PJRT worker, execution).
#[derive(Debug, Clone)]
pub struct RuntimeError(pub String);

impl RuntimeError {
    pub fn new(msg: impl Into<String>) -> Self {
        Self(msg.into())
    }
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RuntimeError {}

/// Result alias used throughout the runtime layer (anyhow-style default
/// error parameter, so `Result<T, String>` remains expressible).
pub type Result<T, E = RuntimeError> = std::result::Result<T, E>;

/// Attach context to an error, `anyhow::Context`-style.
pub(crate) trait Context<T> {
    fn context(self, msg: impl Into<String>) -> Result<T>;
    fn with_context(self, f: impl FnOnce() -> String) -> Result<T>;
}

impl<T, E: std::fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl Into<String>) -> Result<T> {
        self.map_err(|e| RuntimeError(format!("{}: {e}", msg.into())))
    }
    fn with_context(self, f: impl FnOnce() -> String) -> Result<T> {
        self.map_err(|e| RuntimeError(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl Into<String>) -> Result<T> {
        self.ok_or_else(|| RuntimeError(msg.into()))
    }
    fn with_context(self, f: impl FnOnce() -> String) -> Result<T> {
        self.ok_or_else(|| RuntimeError(f()))
    }
}
