//! PJRT client interface: HLO text -> compiled executable -> execution
//! with f32 buffers.
//!
//! This build ships the **stub** implementation so the crate carries no
//! FFI dependency and compiles fully offline. The stub preserves the whole
//! API surface — [`PjrtRuntime::cpu`] succeeds, artifact *loading* fails
//! with a descriptive error — so [`super::registry::ArtifactRegistry`]
//! discovery, variant selection, and worker plumbing all run and are
//! testable, while `GpArtifactBackend` callers fall back to the pure-Rust
//! GP backend exactly as they do when no artifacts have been built.
//!
//! To re-enable the real backend, add the `xla` FFI crate (xla_extension)
//! to Cargo.toml and restore the binding here: the real implementation is
//! `HloModuleProto::from_text_file` -> `XlaComputation::from_proto` ->
//! `PjRtClient::compile`, executing via `Literal` buffers. Interchange is
//! HLO *text*; serialized protos from jax >= 0.5 carry 64-bit instruction
//! ids that xla_extension 0.5.1 rejects. The handles are `!Send`
//! (Rc + raw FFI pointers), which is why these types are confined to the
//! dedicated PJRT worker thread spawned by the registry; the rest of the
//! system talks to it through a channel.

use super::{Result, RuntimeError};
use std::path::Path;

/// A PJRT CPU client (one per worker thread).
pub struct PjrtRuntime {
    platform: String,
}

impl PjrtRuntime {
    pub fn cpu() -> Result<Self> {
        Ok(Self {
            platform: "stub-cpu".to_string(),
        })
    }

    pub fn platform(&self) -> String {
        self.platform.clone()
    }

    /// Load and compile one HLO-text artifact.
    pub fn load_hlo_text(&self, path: &Path) -> Result<PjrtExecutable> {
        Err(RuntimeError(format!(
            "cannot compile {}: built without the xla FFI crate (stub PJRT \
             runtime; see runtime/pjrt.rs docs)",
            path.display()
        )))
    }
}

/// One compiled executable (worker-thread local).
pub struct PjrtExecutable {
    _private: (),
}

/// An owned f32 input tensor (f64 storage for convenience; converted at
/// the FFI boundary). `dims` empty = scalar.
#[derive(Debug, Clone)]
pub struct TensorInput {
    pub data: Vec<f64>,
    pub dims: Vec<usize>,
}

impl TensorInput {
    pub fn scalar(v: f64) -> Self {
        Self {
            data: vec![v],
            dims: vec![],
        }
    }

    pub fn vec1(data: Vec<f64>) -> Self {
        let dims = vec![data.len()];
        Self { data, dims }
    }

    pub fn mat(data: Vec<f64>, rows: usize, cols: usize) -> Self {
        assert_eq!(data.len(), rows * cols);
        Self {
            data,
            dims: vec![rows, cols],
        }
    }
}

impl PjrtExecutable {
    /// Execute with f32 tensors; the artifact returns a 1-tuple whose
    /// element is flattened into the result vector.
    pub fn run_f32(&self, inputs: &[TensorInput]) -> Result<Vec<f64>> {
        for input in inputs {
            let expected: usize = input.dims.iter().product();
            if expected != input.data.len() {
                return Err(RuntimeError(format!(
                    "input size {} != dims {:?}",
                    input.data.len(),
                    input.dims
                )));
            }
        }
        Err(RuntimeError(
            "stub PJRT runtime cannot execute artifacts".to_string(),
        ))
    }
}
