//! Thin wrapper over the `xla` crate's PJRT client: HLO text ->
//! compiled executable -> execution with f32 buffers.
//!
//! The xla crate's handles are `!Send` (Rc + raw FFI pointers), so these
//! types are confined to the dedicated PJRT worker thread spawned by
//! [`super::registry::ArtifactRegistry`]; the rest of the system talks to
//! it through a channel.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`); serialized
//! protos from jax >= 0.5 carry 64-bit instruction ids that xla_extension
//! 0.5.1 rejects. See /opt/xla-example/README.md and DESIGN.md §3.

use anyhow::{Context, Result};
use std::path::Path;

/// A PJRT CPU client (one per worker thread).
pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

impl PjrtRuntime {
    pub fn cpu() -> Result<Self> {
        Ok(Self {
            client: xla::PjRtClient::cpu().context("create PJRT CPU client")?,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile one HLO-text artifact.
    pub fn load_hlo_text(&self, path: &Path) -> Result<PjrtExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {}", path.display()))?;
        Ok(PjrtExecutable { exe })
    }
}

/// One compiled executable (worker-thread local).
pub struct PjrtExecutable {
    exe: xla::PjRtLoadedExecutable,
}

/// An owned f32 input tensor (f64 storage for convenience; converted at
/// the FFI boundary). `dims` empty = scalar.
#[derive(Debug, Clone)]
pub struct TensorInput {
    pub data: Vec<f64>,
    pub dims: Vec<usize>,
}

impl TensorInput {
    pub fn scalar(v: f64) -> Self {
        Self {
            data: vec![v],
            dims: vec![],
        }
    }

    pub fn vec1(data: Vec<f64>) -> Self {
        let dims = vec![data.len()];
        Self { data, dims }
    }

    pub fn mat(data: Vec<f64>, rows: usize, cols: usize) -> Self {
        assert_eq!(data.len(), rows * cols);
        Self {
            data,
            dims: vec![rows, cols],
        }
    }
}

impl PjrtExecutable {
    /// Execute with f32 tensors; the artifact returns a 1-tuple whose
    /// element is flattened into the result vector.
    pub fn run_f32(&self, inputs: &[TensorInput]) -> Result<Vec<f64>> {
        let mut literals = Vec::with_capacity(inputs.len());
        for input in inputs {
            let expected: usize = input.dims.iter().product();
            anyhow::ensure!(
                expected == input.data.len(),
                "input size {} != dims {:?}",
                input.data.len(),
                input.dims
            );
            let f32s: Vec<f32> = input.data.iter().map(|&v| v as f32).collect();
            let lit = xla::Literal::vec1(&f32s);
            let dims_i64: Vec<i64> = input.dims.iter().map(|&d| d as i64).collect();
            let lit = if input.dims.len() == 1 {
                lit
            } else {
                lit.reshape(&dims_i64)?
            };
            literals.push(lit);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // Lowered with return_tuple=True -> unwrap the 1-tuple.
        let out = result.to_tuple1()?;
        let values = out.to_vec::<f32>()?;
        Ok(values.into_iter().map(|v| v as f64).collect())
    }
}
