//! GP backend executing the AOT-compiled JAX/Pallas artifact via PJRT.
//!
//! Implements [`crate::policies::gp_bandit::GpBackend`] with the same
//! semantics as the pure-Rust backend (validated against it in
//! `rust/tests/artifact_parity.rs`): inputs are padded to the artifact's
//! static shapes — extra rows are masked out (row mask), extra dims are
//! zero columns (distance-preserving), extra candidates are discarded on
//! the way out.

use super::registry::{ArtifactRegistry, VariantKey};
use crate::policies::gp_bandit::{GpBackend, UCB_BETA};
use crate::pythia::policy::PolicyError;
use crate::runtime::pjrt::TensorInput;

/// PJRT-backed GP scorer.
pub struct GpArtifactBackend {
    registry: &'static ArtifactRegistry,
}

impl GpArtifactBackend {
    /// Use the process-global registry (None if `make artifacts` has not
    /// been run — callers fall back to the Rust backend).
    pub fn from_global() -> Option<Self> {
        ArtifactRegistry::global().map(|registry| Self { registry })
    }

    pub fn new(registry: &'static ArtifactRegistry) -> Self {
        Self { registry }
    }

    pub fn variants(&self) -> Vec<VariantKey> {
        self.registry.variant_keys()
    }
}

impl GpBackend for GpArtifactBackend {
    fn score(
        &self,
        x_train: &[Vec<f64>],
        y_train: &[f64],
        candidates: &[Vec<f64>],
        noise_high: bool,
    ) -> Result<Vec<f64>, PolicyError> {
        let internal =
            |e: crate::runtime::RuntimeError| PolicyError::Internal(format!("pjrt backend: {e}"));
        let n_real = x_train.len();
        let d_real = x_train.first().map(|r| r.len()).unwrap_or(1);
        let m_real = candidates.len();
        let key = self
            .registry
            .pick(n_real, d_real, m_real)
            .ok_or_else(|| {
                PolicyError::Unsupported(format!(
                    "no artifact variant fits n={n_real} d={d_real} m={m_real} \
                     (available: {:?})",
                    self.registry.variant_keys()
                ))
            })?;
        // Pad x (n_pad x d_pad), y (n_pad), mask (n_pad), candidates (m x d_pad).
        let mut x = vec![0.0f64; key.n * key.d];
        for (i, row) in x_train.iter().enumerate() {
            for (j, &v) in row.iter().enumerate() {
                x[i * key.d + j] = v;
            }
        }
        let mut y = vec![0.0f64; key.n];
        y[..n_real].copy_from_slice(y_train);
        let mut mask = vec![0.0f64; key.n];
        for m in mask.iter_mut().take(n_real) {
            *m = 1.0;
        }
        let mut cand = vec![0.0f64; key.m * key.d];
        for (i, row) in candidates.iter().enumerate() {
            for (j, &v) in row.iter().enumerate() {
                cand[i * key.d + j] = v;
            }
        }
        let noise = if noise_high { 1e-2 } else { 1e-6 };

        let out = self
            .registry
            .execute(
                key,
                vec![
                    TensorInput::mat(x, key.n, key.d),
                    TensorInput::vec1(y),
                    TensorInput::vec1(mask),
                    TensorInput::mat(cand, key.m, key.d),
                    TensorInput::scalar(noise),
                    TensorInput::scalar(UCB_BETA),
                ],
            )
            .map_err(internal)?;
        // Discard scores for padded candidate slots.
        Ok(out.into_iter().take(m_real).collect())
    }

    fn backend_name(&self) -> &str {
        "pjrt-artifact-gp"
    }
}
