//! Artifact registry: discovers compiled `gp_suggest` variants from
//! `artifacts/manifest.json` and executes them on a dedicated PJRT worker
//! thread (the xla crate's handles are `!Send`; confining them to one
//! thread gives the rest of the system a `Send + Sync` interface).

use super::pjrt::{PjrtRuntime, TensorInput};
use super::{Context, Result, RuntimeError};
use crate::util::json::{parse, Json};
use crate::util::sync::{classes, Mutex};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::OnceLock;

/// A padded-shape variant key.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct VariantKey {
    pub n: usize,
    pub d: usize,
    pub m: usize,
}

struct Job {
    key: VariantKey,
    inputs: Vec<TensorInput>,
    reply: mpsc::Sender<Result<Vec<f64>, String>>,
}

/// Discovered artifacts + the PJRT worker channel.
pub struct ArtifactRegistry {
    variants: Vec<VariantKey>,
    sender: Mutex<mpsc::Sender<Job>>,
}

impl ArtifactRegistry {
    /// Open the registry at `dir` (expects `manifest.json` from aot.py)
    /// and spawn the PJRT worker.
    pub fn open(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("read {}", manifest_path.display()))?;
        let doc = parse(&text).map_err(|e| RuntimeError(format!("manifest json: {e}")))?;
        let mut table: Vec<(VariantKey, String)> = Vec::new();
        for v in doc
            .get("variants")
            .and_then(Json::as_arr)
            .context("manifest missing variants")?
        {
            let get = |k: &str| -> Result<usize> {
                Ok(v.get(k)
                    .and_then(Json::as_i64)
                    .with_context(|| format!("variant missing {k}"))? as usize)
            };
            let key = VariantKey {
                n: get("n")?,
                d: get("d")?,
                m: get("m")?,
            };
            let file = v
                .get("file")
                .and_then(Json::as_str)
                .context("variant missing file")?
                .to_string();
            table.push((key, file));
        }
        table.sort_by_key(|(k, _)| *k);
        let variants: Vec<VariantKey> = table.iter().map(|(k, _)| *k).collect();

        // Spawn the worker that owns all PJRT state. Startup errors are
        // reported through a handshake channel.
        let (sender, receiver) = mpsc::channel::<Job>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        std::thread::Builder::new()
            .name("pjrt-worker".into())
            .spawn(move || pjrt_worker(dir, table, receiver, ready_tx))
            .context("spawn pjrt worker")?;
        ready_rx
            .recv()
            .context("pjrt worker handshake")?
            .map_err(|e| RuntimeError(format!("pjrt init: {e}")))?;
        Ok(Self {
            variants,
            sender: Mutex::new(&classes::RT_PJRT, sender),
        })
    }

    /// The process-wide registry rooted at `$OSSVIZIER_ARTIFACTS` or
    /// `./artifacts` (None if artifacts have not been built).
    pub fn global() -> Option<&'static ArtifactRegistry> {
        static GLOBAL: OnceLock<Option<ArtifactRegistry>> = OnceLock::new();
        GLOBAL
            .get_or_init(|| {
                let dir = std::env::var("OSSVIZIER_ARTIFACTS")
                    .unwrap_or_else(|_| "artifacts".to_string());
                ArtifactRegistry::open(dir).ok()
            })
            .as_ref()
    }

    pub fn variant_keys(&self) -> Vec<VariantKey> {
        self.variants.clone()
    }

    /// Smallest variant with `n >= n_real`, `d >= d_real`, `m >= m_real`.
    pub fn pick(&self, n_real: usize, d_real: usize, m_real: usize) -> Option<VariantKey> {
        self.variants
            .iter()
            .copied()
            .filter(|k| k.n >= n_real && k.d >= d_real && k.m >= m_real)
            .min_by_key(|k| (k.n, k.d, k.m))
    }

    /// Execute a variant with the given inputs (blocks on the worker).
    pub fn execute(&self, key: VariantKey, inputs: Vec<TensorInput>) -> Result<Vec<f64>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.sender
            .lock()
            .send(Job {
                key,
                inputs,
                reply: reply_tx,
            })
            .map_err(|_| RuntimeError::new("pjrt worker gone"))?;
        reply_rx
            .recv()
            .context("pjrt worker dropped the reply")?
            .map_err(RuntimeError)
    }
}

/// The worker: owns the PJRT client and compiled executables.
fn pjrt_worker(
    dir: PathBuf,
    table: Vec<(VariantKey, String)>,
    jobs: mpsc::Receiver<Job>,
    ready: mpsc::Sender<Result<(), String>>,
) {
    let runtime = match PjrtRuntime::cpu() {
        Ok(r) => {
            let _ = ready.send(Ok(()));
            r
        }
        Err(e) => {
            let _ = ready.send(Err(e.to_string()));
            return;
        }
    };
    let mut compiled: HashMap<VariantKey, super::pjrt::PjrtExecutable> = HashMap::new();
    while let Ok(job) = jobs.recv() {
        let result = (|| -> Result<Vec<f64>, String> {
            if !compiled.contains_key(&job.key) {
                let file = table
                    .iter()
                    .find(|(k, _)| *k == job.key)
                    .map(|(_, f)| f.clone())
                    .ok_or_else(|| format!("unknown variant {:?}", job.key))?;
                let exe = runtime
                    .load_hlo_text(&dir.join(file))
                    .map_err(|e| e.to_string())?;
                compiled.insert(job.key, exe);
            }
            compiled[&job.key]
                .run_f32(&job.inputs)
                .map_err(|e| e.to_string())
        })();
        let _ = job.reply.send(result);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &std::path::Path, variants: &[(usize, usize, usize)]) {
        let items: Vec<String> = variants
            .iter()
            .map(|(n, d, m)| {
                format!(
                    r#"{{"n": {n}, "d": {d}, "m": {m}, "file": "gp_suggest_n{n}_d{d}_m{m}.hlo.txt"}}"#
                )
            })
            .collect();
        std::fs::write(
            dir.join("manifest.json"),
            format!(r#"{{"model": "gp_suggest", "variants": [{}]}}"#, items.join(",")),
        )
        .unwrap();
    }

    fn tmpdir() -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "ossvizier-registry-{}-{}",
            std::process::id(),
            crate::util::id::next_uid()
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn picks_smallest_fitting_variant() {
        let dir = tmpdir();
        write_manifest(&dir, &[(32, 8, 256), (128, 8, 256), (256, 16, 256)]);
        let reg = ArtifactRegistry::open(&dir).unwrap();
        assert_eq!(reg.variant_keys().len(), 3);
        assert_eq!(reg.pick(10, 4, 256), Some(VariantKey { n: 32, d: 8, m: 256 }));
        assert_eq!(reg.pick(100, 8, 256), Some(VariantKey { n: 128, d: 8, m: 256 }));
        assert_eq!(reg.pick(100, 9, 256), Some(VariantKey { n: 256, d: 16, m: 256 }));
        assert_eq!(reg.pick(1000, 4, 256), None, "too many rows for any variant");
        assert_eq!(reg.pick(10, 99, 256), None, "too many dims");
    }

    #[test]
    fn missing_manifest_is_error() {
        let dir = tmpdir();
        assert!(ArtifactRegistry::open(&dir).is_err());
    }

    #[test]
    fn unknown_variant_execution_is_error() {
        let dir = tmpdir();
        write_manifest(&dir, &[(32, 8, 256)]);
        let reg = ArtifactRegistry::open(&dir).unwrap();
        let err = reg
            .execute(VariantKey { n: 1, d: 1, m: 1 }, vec![])
            .unwrap_err();
        assert!(err.to_string().contains("unknown variant"), "{err}");
    }
}
