//! Proto <-> PyVizier conversions (paper Table 2 and Appendix D.3).
//!
//! | proto (wire::messages)    | PyVizier (this module's targets)      |
//! |---------------------------|---------------------------------------|
//! | `StudyProto`              | `StudyConfig` (+ name/state)          |
//! | `StudySpecProto`          | `SearchSpace` + `StudyConfig`         |
//! | `ParameterSpecProto`      | `ParameterConfig`                     |
//! | `TrialProto`              | `Trial`                               |
//! | `ParamValue`              | `ParameterValue`                      |
//! | `MetricSpecProto`         | `MetricInformation`                   |
//! | `Measurement` (wire)      | `Measurement` (pyvizier)              |

use super::metadata::Metadata;
use super::parameter::{ParameterDict, ParameterValue};
use super::search_space::{ParameterConfig, ParameterKind, SearchSpace};
use super::study_config::{Algorithm, MetricInformation, StudyConfig};
use super::trial::{Measurement, Trial};
use crate::wire::messages as pb;

// --- ParameterValue ---------------------------------------------------------

pub fn value_to_proto(v: &ParameterValue) -> pb::ParamValue {
    match v {
        ParameterValue::F64(x) => pb::ParamValue::F64(*x),
        ParameterValue::I64(x) => pb::ParamValue::I64(*x),
        ParameterValue::Str(s) => pb::ParamValue::Str(s.clone()),
        ParameterValue::Bool(b) => pb::ParamValue::Bool(*b),
    }
}

pub fn value_from_proto(v: &pb::ParamValue) -> ParameterValue {
    match v {
        pb::ParamValue::F64(x) => ParameterValue::F64(*x),
        pb::ParamValue::I64(x) => ParameterValue::I64(*x),
        pb::ParamValue::Str(s) => ParameterValue::Str(s.clone()),
        pb::ParamValue::Bool(b) => ParameterValue::Bool(*b),
    }
}

// --- Metadata ----------------------------------------------------------------

pub fn metadata_to_proto(m: &Metadata) -> Vec<pb::MetadataItem> {
    m.iter()
        .map(|(ns, k, v)| pb::MetadataItem {
            namespace: ns.to_string(),
            key: k.to_string(),
            value: v.to_vec(),
        })
        .collect()
}

pub fn metadata_from_proto(items: &[pb::MetadataItem]) -> Metadata {
    let mut m = Metadata::new();
    for item in items {
        m.put(&item.namespace, &item.key, item.value.clone());
    }
    m
}

// --- Measurement --------------------------------------------------------------

pub fn measurement_to_proto(m: &Measurement) -> pb::Measurement {
    pb::Measurement {
        step_count: m.step,
        elapsed_secs: m.elapsed_secs,
        metrics: m
            .metrics
            .iter()
            .map(|(k, v)| pb::Metric {
                metric_id: k.clone(),
                value: *v,
            })
            .collect(),
    }
}

pub fn measurement_from_proto(m: &pb::Measurement) -> Measurement {
    Measurement {
        step: m.step_count,
        elapsed_secs: m.elapsed_secs,
        metrics: m.metrics.iter().map(|x| (x.metric_id.clone(), x.value)).collect(),
    }
}

// --- Trial ---------------------------------------------------------------------

pub fn trial_to_proto(t: &Trial) -> pb::TrialProto {
    pb::TrialProto {
        id: t.id,
        state: t.state,
        parameters: t
            .parameters
            .iter()
            .map(|(k, v)| pb::TrialParameter {
                parameter_id: k.clone(),
                value: value_to_proto(v),
            })
            .collect(),
        final_measurement: t.final_measurement.as_ref().map(measurement_to_proto),
        measurements: t.measurements.iter().map(measurement_to_proto).collect(),
        client_id: t.client_id.clone(),
        infeasibility_reason: t.infeasibility_reason.clone().unwrap_or_default(),
        metadata: metadata_to_proto(&t.metadata),
        created_ms: t.created_ms,
        completed_ms: t.completed_ms,
    }
}

pub fn trial_from_proto(p: &pb::TrialProto) -> Trial {
    Trial {
        id: p.id,
        state: p.state,
        parameters: p
            .parameters
            .iter()
            .map(|tp| (tp.parameter_id.clone(), value_from_proto(&tp.value)))
            .collect(),
        measurements: p.measurements.iter().map(measurement_from_proto).collect(),
        final_measurement: p.final_measurement.as_ref().map(measurement_from_proto),
        client_id: p.client_id.clone(),
        infeasibility_reason: if p.infeasibility_reason.is_empty() {
            None
        } else {
            Some(p.infeasibility_reason.clone())
        },
        metadata: metadata_from_proto(&p.metadata),
        created_ms: p.created_ms,
        completed_ms: p.completed_ms,
    }
}

// --- ParameterConfig -------------------------------------------------------------

pub fn parameter_config_to_proto(c: &ParameterConfig) -> pb::ParameterSpecProto {
    pb::ParameterSpecProto {
        parameter_id: c.name.clone(),
        kind: match &c.kind {
            ParameterKind::Double { min, max } => pb::ParameterKind::Double { min: *min, max: *max },
            ParameterKind::Integer { min, max } => pb::ParameterKind::Integer { min: *min, max: *max },
            ParameterKind::Discrete { values } => pb::ParameterKind::Discrete { values: values.clone() },
            ParameterKind::Categorical { values } => {
                pb::ParameterKind::Categorical { values: values.clone() }
            }
        },
        scale_type: c.scale,
        conditional_children: c
            .children
            .iter()
            .map(|(pv, child)| pb::ConditionalParameterSpec {
                parent_values: pb::ParentValues {
                    values: pv.iter().map(value_to_proto).collect(),
                },
                spec: parameter_config_to_proto(child),
            })
            .collect(),
    }
}

pub fn parameter_config_from_proto(p: &pb::ParameterSpecProto) -> ParameterConfig {
    ParameterConfig {
        name: p.parameter_id.clone(),
        kind: match &p.kind {
            pb::ParameterKind::Double { min, max } => ParameterKind::Double { min: *min, max: *max },
            pb::ParameterKind::Integer { min, max } => ParameterKind::Integer { min: *min, max: *max },
            pb::ParameterKind::Discrete { values } => ParameterKind::Discrete { values: values.clone() },
            pb::ParameterKind::Categorical { values } => {
                ParameterKind::Categorical { values: values.clone() }
            }
        },
        scale: p.scale_type,
        children: p
            .conditional_children
            .iter()
            .map(|c| {
                (
                    c.parent_values.values.iter().map(value_from_proto).collect(),
                    parameter_config_from_proto(&c.spec),
                )
            })
            .collect(),
    }
}

// --- MetricInformation -------------------------------------------------------------

pub fn metric_to_proto(m: &MetricInformation) -> pb::MetricSpecProto {
    pb::MetricSpecProto {
        metric_id: m.name.clone(),
        goal: m.goal,
        min_value: m.min_value,
        max_value: m.max_value,
    }
}

pub fn metric_from_proto(p: &pb::MetricSpecProto) -> MetricInformation {
    MetricInformation {
        name: p.metric_id.clone(),
        goal: p.goal,
        min_value: p.min_value,
        max_value: p.max_value,
    }
}

// --- StudyConfig <-> StudySpecProto --------------------------------------------------

pub fn study_config_to_proto(c: &StudyConfig) -> pb::StudySpecProto {
    pb::StudySpecProto {
        parameters: c.search_space.roots.iter().map(parameter_config_to_proto).collect(),
        metrics: c.metrics.iter().map(metric_to_proto).collect(),
        algorithm: c.algorithm.as_str().to_string(),
        observation_noise: c.observation_noise,
        stopping: c.stopping.clone(),
        metadata: metadata_to_proto(&c.metadata),
        seed: c.seed,
    }
}

pub fn study_config_from_proto(display_name: &str, p: &pb::StudySpecProto) -> StudyConfig {
    StudyConfig {
        display_name: display_name.to_string(),
        search_space: SearchSpace {
            roots: p.parameters.iter().map(parameter_config_from_proto).collect(),
        },
        metrics: p.metrics.iter().map(metric_from_proto).collect(),
        algorithm: Algorithm::from_str(&p.algorithm),
        observation_noise: p.observation_noise,
        stopping: p.stopping.clone(),
        metadata: metadata_from_proto(&p.metadata),
        seed: p.seed,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pyvizier::trial::TrialState;
    use crate::testing::prop::{check, Gen};
    use crate::wire::codec::{decode, encode};
    use crate::wire::messages::{MetricGoal, ScaleType};

    fn gen_value(g: &mut Gen) -> ParameterValue {
        match g.u64_below(4) {
            0 => ParameterValue::F64(g.f64_any()),
            1 => ParameterValue::I64(g.i64_range(i64::MIN / 2, i64::MAX / 2)),
            2 => ParameterValue::Str(g.string(12)),
            _ => ParameterValue::Bool(g.bool()),
        }
    }

    fn gen_config(g: &mut Gen, depth: usize) -> ParameterConfig {
        let name = g.ident(8);
        let mut cfg = match g.u64_below(4) {
            0 => {
                let lo = g.f64_range(-100.0, 100.0);
                ParameterConfig::double(&name, lo, lo + g.f64_range(0.0, 50.0))
            }
            1 => {
                let lo = g.i64_range(-50, 50);
                ParameterConfig::integer(&name, lo, lo + g.i64_range(0, 20))
            }
            2 => ParameterConfig::discrete(&name, (0..g.usize_range(1, 5)).map(|i| i as f64).collect()),
            _ => ParameterConfig::categorical(&name, vec!["a", "b", "c"]),
        };
        if cfg.is_numeric() && g.bool() {
            cfg.scale = ScaleType::Linear; // keep valid without positivity checks
        }
        if depth > 0 && g.bool() {
            let child = gen_config(g, depth - 1);
            cfg = cfg.with_child(vec![gen_value(g)], child);
        }
        cfg
    }

    #[test]
    fn prop_trial_roundtrip_through_proto_and_wire() {
        check("trial -> proto -> bytes -> proto -> trial", 150, |g| {
            let mut t = Trial::new(g.u64_below(1 << 40), ParameterDict::new());
            for _ in 0..g.usize_range(0, 5) {
                let name = g.ident(6);
                let v = gen_value(g);
                t.parameters.set(name, v);
            }
            t.state = *g.pick(&[
                TrialState::Requested,
                TrialState::Active,
                TrialState::Stopping,
                TrialState::Completed,
                TrialState::Infeasible,
            ]);
            if g.bool() {
                let mut m = Measurement::new(g.i64_range(0, 1000));
                m.metrics.insert(g.ident(5), g.f64_range(-10.0, 10.0));
                t.final_measurement = Some(m);
            }
            for step in 0..g.i64_range(0, 4) {
                t.measurements.push(Measurement::new(step).with_metric("m", g.f64_range(0.0, 1.0)));
            }
            if g.bool() {
                t.infeasibility_reason = Some(g.string(10));
                // Empty string means "feasible" on the wire; avoid ambiguity.
                if t.infeasibility_reason.as_deref() == Some("") {
                    t.infeasibility_reason = Some("x".into());
                }
            }
            t.metadata.put_str(&g.ident(4), &g.ident(4), &g.string(8));
            t.client_id = g.ident(6);
            t.created_ms = g.u64_below(1 << 40);
            t.completed_ms = g.u64_below(1 << 40);

            let proto = trial_to_proto(&t);
            let bytes = encode(&proto);
            let proto2: pb::TrialProto = decode(&bytes).unwrap();
            let back = trial_from_proto(&proto2);
            assert_eq!(back, t);
        });
    }

    #[test]
    fn prop_study_config_roundtrip() {
        check("study config -> proto -> bytes -> config", 100, |g| {
            let mut c = StudyConfig::new("demo");
            for _ in 0..g.usize_range(1, 4) {
                c.search_space.add_param(gen_config(g, 2));
            }
            c.add_metric(MetricInformation::maximize(&g.ident(5)));
            if g.bool() {
                c.add_metric(MetricInformation {
                    name: format!("second_{}", g.ident(4)),
                    goal: MetricGoal::Minimize,
                    min_value: 0.0,
                    max_value: 100.0,
                });
            }
            let algos = ["RANDOM_SEARCH", "GP_BANDIT", "NSGA2", "MY_CUSTOM"];
            c.algorithm = Algorithm::from_str(*g.pick(&algos));
            c.seed = g.u64_below(1 << 30);
            c.metadata.put_str("ns", "k", &g.string(6));

            let proto = study_config_to_proto(&c);
            let bytes = encode(&proto);
            let proto2: pb::StudySpecProto = decode(&bytes).unwrap();
            let back = study_config_from_proto("demo", &proto2);
            assert_eq!(back, c);
        });
    }

    #[test]
    fn table2_name_pairs_all_covered() {
        // A compile-time checklist of Table 2: each converter exists and
        // round-trips a minimal instance.
        let v = ParameterValue::F64(1.0);
        assert_eq!(value_from_proto(&value_to_proto(&v)), v);

        let m = Measurement::new(1).with_metric("a", 2.0);
        assert_eq!(measurement_from_proto(&measurement_to_proto(&m)), m);

        let t = Trial::new(1, ParameterDict::new());
        assert_eq!(trial_from_proto(&trial_to_proto(&t)), t);

        let pcfg = ParameterConfig::double("x", 0.0, 1.0);
        assert_eq!(parameter_config_from_proto(&parameter_config_to_proto(&pcfg)), pcfg);

        let mi = MetricInformation::maximize("m");
        assert_eq!(metric_from_proto(&metric_to_proto(&mi)), mi);

        let mut sc = StudyConfig::new("s");
        sc.add_metric(MetricInformation::maximize("m"));
        assert_eq!(study_config_from_proto("s", &study_config_to_proto(&sc)), sc);
    }
}

// --- JSON helpers for designer state (paper Code Block 7 dumps JSON) -------------

use crate::util::json::Json;

/// Serialize a parameter dict to a JSON object (typed: numbers keep their
/// f64/i64 distinction via a one-char tag).
pub fn params_to_json(p: &ParameterDict) -> Json {
    let mut obj = Json::obj();
    for (k, v) in p.iter() {
        let tagged = match v {
            ParameterValue::F64(x) => {
                let mut o = Json::obj();
                o.set("f", Json::Num(*x));
                o
            }
            ParameterValue::I64(x) => {
                let mut o = Json::obj();
                o.set("i", Json::Num(*x as f64));
                o
            }
            ParameterValue::Str(s) => {
                let mut o = Json::obj();
                o.set("s", Json::Str(s.clone()));
                o
            }
            ParameterValue::Bool(b) => {
                let mut o = Json::obj();
                o.set("b", Json::Bool(*b));
                o
            }
        };
        obj.set(k, tagged);
    }
    obj
}

/// Inverse of [`params_to_json`].
pub fn params_from_json(j: &Json) -> Option<ParameterDict> {
    let obj = j.as_obj()?;
    let mut p = ParameterDict::new();
    for (k, tagged) in obj {
        let v = if let Some(x) = tagged.get("f") {
            ParameterValue::F64(x.as_f64()?)
        } else if let Some(x) = tagged.get("i") {
            ParameterValue::I64(x.as_i64()?)
        } else if let Some(x) = tagged.get("s") {
            ParameterValue::Str(x.as_str()?.to_string())
        } else if let Some(x) = tagged.get("b") {
            ParameterValue::Bool(x.as_bool()?)
        } else {
            return None;
        };
        p.set(k.clone(), v);
    }
    Some(p)
}

#[cfg(test)]
mod json_tests {
    use super::*;
    use crate::testing::prop::check;

    #[test]
    fn prop_params_json_roundtrip() {
        check("params json roundtrip", 100, |g| {
            let mut p = ParameterDict::new();
            for _ in 0..g.usize_range(0, 6) {
                let name = g.ident(8);
                match g.u64_below(4) {
                    0 => p.set(name, g.f64_range(-1e6, 1e6)),
                    1 => p.set(name, g.i64_range(-1 << 40, 1 << 40)),
                    2 => p.set(name, g.string(10)),
                    _ => p.set(name, g.bool()),
                };
            }
            let j = params_to_json(&p);
            let text = j.to_string();
            let back = params_from_json(&crate::util::json::parse(&text).unwrap()).unwrap();
            assert_eq!(back, p);
        });
    }
}
