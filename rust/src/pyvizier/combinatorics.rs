//! Combinatorial reparameterizations (paper Appendix A).
//!
//! The paper recommends representing combinatorial objects (permutations,
//! subsets, graphs) through surjective mappings Φ: Z → X from spaces Z that
//! Vizier's flat `ParameterSpec`s can express. This module implements the
//! two codes named in Appendix A.1.1 — the Lehmer code for permutations and
//! the analogous shrinking-index code for k-subsets — plus helpers for the
//! infeasibility-lifting pattern of A.1.2.

use super::parameter::ParameterDict;
use super::search_space::{ParameterConfig, SearchSpace};

/// Build the search space Z = [n] × [n-1] × ... × [1] whose points decode
/// to permutations of `[0, n)` via [`decode_permutation`].
pub fn permutation_space(prefix: &str, n: usize) -> SearchSpace {
    let mut space = SearchSpace::new();
    for i in 0..n {
        space.add_param(ParameterConfig::integer(
            &format!("{prefix}{i}"),
            0,
            (n - 1 - i) as i64,
        ));
    }
    space
}

/// Decode a Lehmer code (one digit per parameter `prefix{i}`, digit i in
/// `[0, n-i)`) into a permutation of `[0, n)`.
pub fn decode_permutation(prefix: &str, n: usize, params: &ParameterDict) -> Option<Vec<usize>> {
    let mut remaining: Vec<usize> = (0..n).collect();
    let mut perm = Vec::with_capacity(n);
    for i in 0..n {
        let d = params.get_i64(&format!("{prefix}{i}"))? as usize;
        if d >= remaining.len() {
            return None;
        }
        perm.push(remaining.remove(d));
    }
    Some(perm)
}

/// Encode a permutation of `[0, n)` into its Lehmer code digits.
pub fn encode_permutation(prefix: &str, perm: &[usize]) -> ParameterDict {
    let mut remaining: Vec<usize> = (0..perm.len()).collect();
    let mut params = ParameterDict::new();
    for (i, &p) in perm.iter().enumerate() {
        let d = remaining.iter().position(|&r| r == p).expect("valid permutation");
        remaining.remove(d);
        params.set(format!("{prefix}{i}"), d as i64);
    }
    params
}

/// Build the space Z = [n] × [n-1] × ... × [n-k+1] for k-subsets of `[0, n)`.
pub fn subset_space(prefix: &str, n: usize, k: usize) -> SearchSpace {
    assert!(k <= n);
    let mut space = SearchSpace::new();
    for i in 0..k {
        space.add_param(ParameterConfig::integer(
            &format!("{prefix}{i}"),
            0,
            (n - 1 - i) as i64,
        ));
    }
    space
}

/// Decode the shrinking-index code into a k-subset of `[0, n)`
/// (sorted ascending).
pub fn decode_subset(prefix: &str, n: usize, k: usize, params: &ParameterDict) -> Option<Vec<usize>> {
    let mut remaining: Vec<usize> = (0..n).collect();
    let mut subset = Vec::with_capacity(k);
    for i in 0..k {
        let d = params.get_i64(&format!("{prefix}{i}"))? as usize;
        if d >= remaining.len() {
            return None;
        }
        subset.push(remaining.remove(d));
    }
    subset.sort_unstable();
    Some(subset)
}

/// Flat adjacency-matrix space for digraphs over `n` nodes (Appendix A.1.1's
/// NASBENCH-style graph representation): n*(n-1)/2 upper-triangle booleans
/// as integer params in {0,1}.
pub fn dag_space(prefix: &str, n: usize) -> SearchSpace {
    let mut space = SearchSpace::new();
    for i in 0..n {
        for j in (i + 1)..n {
            space.add_param(ParameterConfig::integer(&format!("{prefix}{i}_{j}"), 0, 1));
        }
    }
    space
}

/// Decode the upper-triangle edge list. Always a DAG under the i<j ordering.
pub fn decode_dag(prefix: &str, n: usize, params: &ParameterDict) -> Option<Vec<(usize, usize)>> {
    let mut edges = Vec::new();
    for i in 0..n {
        for j in (i + 1)..n {
            let bit = params.get_i64(&format!("{prefix}{i}_{j}"))?;
            if bit != 0 {
                edges.push((i, j));
            }
        }
    }
    Some(edges)
}

/// Infeasibility lifting (Appendix A.1.2): wraps a membership test for
/// X ⊂ Z, producing the infeasibility reason Vizier records on the trial.
pub fn check_feasible<F: Fn(&ParameterDict) -> bool>(
    params: &ParameterDict,
    in_x: F,
    reason: &str,
) -> Result<(), String> {
    if in_x(params) {
        Ok(())
    } else {
        Err(reason.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;
    use crate::util::rng::Pcg32;

    #[test]
    fn permutation_space_shape() {
        let s = permutation_space("p", 4);
        assert_eq!(s.num_parameters(), 4);
        assert_eq!(s.cardinality(), Some(24)); // 4! via 4*3*2*1
    }

    #[test]
    fn lehmer_identity_and_reverse() {
        // All-zero digits decode to the identity.
        let mut params = ParameterDict::new();
        for i in 0..5 {
            params.set(format!("p{i}"), 0i64);
        }
        assert_eq!(decode_permutation("p", 5, &params).unwrap(), vec![0, 1, 2, 3, 4]);
        // Max digits decode to the reverse.
        let mut params = ParameterDict::new();
        for i in 0..5 {
            params.set(format!("p{i}"), (4 - i) as i64);
        }
        assert_eq!(decode_permutation("p", 5, &params).unwrap(), vec![4, 3, 2, 1, 0]);
    }

    #[test]
    fn prop_lehmer_bijection() {
        prop::check("lehmer encode/decode bijection", 200, |g| {
            let n = g.usize_range(1, 8);
            // Random permutation.
            let mut perm: Vec<usize> = (0..n).collect();
            g.rng().shuffle(&mut perm);
            let code = encode_permutation("p", &perm);
            let back = decode_permutation("p", n, &code).unwrap();
            assert_eq!(back, perm);
        });
    }

    #[test]
    fn prop_sampled_codes_decode_to_valid_permutations() {
        prop::check("sampled lehmer codes valid", 100, |g| {
            let n = g.usize_range(1, 8);
            let space = permutation_space("p", n);
            let mut rng = Pcg32::seeded(g.u64_below(u64::MAX / 2));
            let params = space.sample(&mut rng);
            let perm = decode_permutation("p", n, &params).unwrap();
            let mut sorted = perm.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, (0..n).collect::<Vec<usize>>());
        });
    }

    #[test]
    fn subset_decoding() {
        let space = subset_space("s", 6, 3);
        assert_eq!(space.num_parameters(), 3);
        let mut rng = Pcg32::seeded(3);
        for _ in 0..100 {
            let params = space.sample(&mut rng);
            let subset = decode_subset("s", 6, 3, &params).unwrap();
            assert_eq!(subset.len(), 3);
            let mut d = subset.clone();
            d.dedup();
            assert_eq!(d.len(), 3, "distinct elements");
            assert!(subset.iter().all(|&x| x < 6));
        }
    }

    #[test]
    fn dag_space_decodes_acyclic_edges() {
        let space = dag_space("e", 4);
        assert_eq!(space.num_parameters(), 6);
        let mut rng = Pcg32::seeded(4);
        let params = space.sample(&mut rng);
        let edges = decode_dag("e", 4, &params).unwrap();
        for (i, j) in edges {
            assert!(i < j, "edge ({i},{j}) violates topological order");
        }
    }

    #[test]
    fn infeasibility_lifting() {
        // Disk X = {||x|| <= 1} inside Z = [-1,1]^2 (the paper's example).
        let mut inside = ParameterDict::new();
        inside.set("x0", 0.5).set("x1", 0.5);
        let norm_ok = |p: &ParameterDict| {
            let x0 = p.get_f64("x0").unwrap();
            let x1 = p.get_f64("x1").unwrap();
            x0 * x0 + x1 * x1 <= 1.0
        };
        assert!(check_feasible(&inside, norm_ok, "outside disk").is_ok());
        let mut outside = ParameterDict::new();
        outside.set("x0", 0.9).set("x1", 0.9);
        assert_eq!(
            check_feasible(&outside, norm_ok, "outside disk"),
            Err("outside disk".to_string())
        );
    }
}
