//! PyVizier-equivalent core types (paper §4, §4.3, Table 2).
//!
//! The paper keeps two representations of every primitive: raw protos for
//! the RPC boundary, and richer "PyVizier" classes with validation and
//! convenient construction. This module is the Rust analogue of the
//! PyVizier layer; [`crate::wire::messages`] is the proto layer, and
//! [`converters`] provides the `to_proto` / `from_proto` mappings of
//! Table 2.

pub mod combinatorics;
pub mod converters;
pub mod metadata;
pub mod parameter;
pub mod pareto;
pub mod scaling;
pub mod search_space;
pub mod study_config;
pub mod trial;

pub use metadata::Metadata;
pub use parameter::{ParameterDict, ParameterValue};
pub use search_space::{ParameterConfig, ParameterKind, SearchSpace};
pub use study_config::{Algorithm, MetricInformation, StudyConfig};
pub use trial::{Measurement, Trial, TrialState, TrialSuggestion};

pub use crate::wire::messages::{MetricGoal, ObservationNoise, ScaleType, StoppingKind, StudyState};
