//! Multi-objective utilities: Pareto dominance, frontier extraction,
//! crowding distance (used by NSGA-II), and 2-D hypervolume.
//!
//! Supports the paper's multi-objective studies (§4.1: "Multiple
//! MetricSpecs will be used for ... finding Pareto frontiers") and the
//! `ListOptimalTrials` RPC.

use super::study_config::MetricInformation;
use super::trial::Trial;

/// Extract a trial's objective vector in *maximization* orientation
/// (minimized metrics are negated). Returns None if any metric is missing.
pub fn objective_vector(trial: &Trial, metrics: &[MetricInformation]) -> Option<Vec<f64>> {
    metrics
        .iter()
        .map(|m| trial.final_metric(&m.name).map(|v| m.maximization_value(v)))
        .collect()
}

/// Does `a` Pareto-dominate `b`? (All coordinates >=, at least one >.)
/// Vectors are in maximization orientation.
pub fn dominates(a: &[f64], b: &[f64]) -> bool {
    debug_assert_eq!(a.len(), b.len());
    let mut strictly = false;
    for (x, y) in a.iter().zip(b) {
        if x < y {
            return false;
        }
        if x > y {
            strictly = true;
        }
    }
    strictly
}

/// Indices of the Pareto-optimal points among `points` (maximization).
/// Simple O(n²) sweep — n here is the number of completed trials, which the
/// paper bounds to "tens to millions"; for the frontier RPC the typical n
/// is small. Duplicate points are all kept.
pub fn pareto_front_indices(points: &[Vec<f64>]) -> Vec<usize> {
    let mut front = Vec::new();
    'outer: for (i, p) in points.iter().enumerate() {
        for (j, q) in points.iter().enumerate() {
            if i != j && dominates(q, p) {
                continue 'outer;
            }
        }
        front.push(i);
    }
    front
}

/// Non-dominated sorting (NSGA-II): returns `ranks[i]` = front index of
/// point i (0 = Pareto-optimal).
pub fn non_dominated_ranks(points: &[Vec<f64>]) -> Vec<usize> {
    let n = points.len();
    let mut dominated_by = vec![0usize; n]; // count of dominators
    let mut dominates_list: Vec<Vec<usize>> = vec![Vec::new(); n];
    for i in 0..n {
        for j in 0..n {
            if i != j && dominates(&points[i], &points[j]) {
                dominates_list[i].push(j);
                dominated_by[j] += 1;
            }
        }
    }
    let mut ranks = vec![usize::MAX; n];
    let mut current: Vec<usize> = (0..n).filter(|&i| dominated_by[i] == 0).collect();
    let mut rank = 0;
    while !current.is_empty() {
        let mut next = Vec::new();
        for &i in &current {
            ranks[i] = rank;
            for &j in &dominates_list[i] {
                dominated_by[j] -= 1;
                if dominated_by[j] == 0 {
                    next.push(j);
                }
            }
        }
        current = next;
        rank += 1;
    }
    ranks
}

/// Crowding distance within one front (NSGA-II diversity preservation).
/// Boundary points get +inf.
pub fn crowding_distance(points: &[Vec<f64>]) -> Vec<f64> {
    let n = points.len();
    if n == 0 {
        return Vec::new();
    }
    let k = points[0].len();
    let mut dist = vec![0.0f64; n];
    for obj in 0..k {
        let mut idx: Vec<usize> = (0..n).collect();
        idx.sort_by(|&a, &b| points[a][obj].partial_cmp(&points[b][obj]).unwrap());
        dist[idx[0]] = f64::INFINITY;
        dist[idx[n - 1]] = f64::INFINITY;
        let span = points[idx[n - 1]][obj] - points[idx[0]][obj];
        if span <= 0.0 {
            continue;
        }
        for w in 1..n - 1 {
            let lo = points[idx[w - 1]][obj];
            let hi = points[idx[w + 1]][obj];
            dist[idx[w]] += (hi - lo) / span;
        }
    }
    dist
}

/// 2-D hypervolume dominated by `points` w.r.t. `reference` (both in
/// maximization orientation; reference must be dominated by all points).
pub fn hypervolume_2d(points: &[Vec<f64>], reference: &[f64; 2]) -> f64 {
    let mut front: Vec<&Vec<f64>> = points
        .iter()
        .filter(|p| p[0] >= reference[0] && p[1] >= reference[1])
        .collect();
    if front.is_empty() {
        return 0.0;
    }
    // Sort by x descending; sweep accumulating strips above the running max y.
    front.sort_by(|a, b| b[0].partial_cmp(&a[0]).unwrap());
    let mut hv = 0.0;
    let mut prev_x = f64::INFINITY;
    let mut max_y = reference[1];
    for p in front {
        let x = p[0].min(prev_x);
        if p[1] > max_y {
            hv += (x - reference[0]) * (p[1] - max_y);
            max_y = p[1];
        }
        prev_x = prev_x.min(p[0]);
    }
    hv
}

/// Select the Pareto-optimal trials (the `ListOptimalTrials` RPC). For a
/// single metric this degenerates to "all trials tied at the best value".
pub fn optimal_trials<'a>(
    trials: impl IntoIterator<Item = &'a Trial>,
    metrics: &[MetricInformation],
) -> Vec<&'a Trial> {
    let complete: Vec<(&Trial, Vec<f64>)> = trials
        .into_iter()
        .filter(|t| t.is_feasible_completed())
        .filter_map(|t| objective_vector(t, metrics).map(|v| (t, v)))
        .collect();
    let points: Vec<Vec<f64>> = complete.iter().map(|(_, v)| v.clone()).collect();
    pareto_front_indices(&points)
        .into_iter()
        .map(|i| complete[i].0)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pyvizier::trial::{Measurement, TrialState};
    use crate::pyvizier::ParameterDict;
    use crate::testing::prop;
    use crate::wire::messages::MetricGoal;

    #[test]
    fn dominance_basics() {
        assert!(dominates(&[2.0, 2.0], &[1.0, 1.0]));
        assert!(dominates(&[2.0, 1.0], &[1.0, 1.0]));
        assert!(!dominates(&[1.0, 1.0], &[1.0, 1.0])); // equal: not strict
        assert!(!dominates(&[2.0, 0.0], &[1.0, 1.0])); // trade-off
    }

    #[test]
    fn front_extraction() {
        let pts = vec![
            vec![1.0, 5.0],
            vec![3.0, 3.0],
            vec![5.0, 1.0],
            vec![2.0, 2.0], // dominated by (3,3)
            vec![0.0, 0.0], // dominated by all
        ];
        assert_eq!(pareto_front_indices(&pts), vec![0, 1, 2]);
    }

    #[test]
    fn ranks_are_layered() {
        let pts = vec![
            vec![3.0, 3.0], // front 0
            vec![2.0, 2.0], // front 1
            vec![1.0, 1.0], // front 2
            vec![1.0, 4.0], // front 0 (trade-off with (3,3))
        ];
        assert_eq!(non_dominated_ranks(&pts), vec![0, 1, 2, 0]);
    }

    #[test]
    fn crowding_boundary_infinite() {
        let pts = vec![vec![0.0, 3.0], vec![1.0, 2.0], vec![2.0, 1.0], vec![3.0, 0.0]];
        let d = crowding_distance(&pts);
        assert!(d[0].is_infinite());
        assert!(d[3].is_infinite());
        assert!(d[1].is_finite() && d[1] > 0.0);
        // Symmetric layout -> equal interior distances.
        assert!((d[1] - d[2]).abs() < 1e-12);
    }

    #[test]
    fn hypervolume_known_value() {
        // Two points (1,2) and (2,1) w.r.t. (0,0): union of two rectangles
        // = 2 + 2 - 1 = 3.
        let pts = vec![vec![1.0, 2.0], vec![2.0, 1.0]];
        assert!((hypervolume_2d(&pts, &[0.0, 0.0]) - 3.0).abs() < 1e-12);
        // Single point.
        assert!((hypervolume_2d(&[vec![2.0, 3.0]], &[0.0, 0.0]) - 6.0).abs() < 1e-12);
        // Point below reference contributes nothing.
        assert_eq!(hypervolume_2d(&[vec![-1.0, -1.0]], &[0.0, 0.0]), 0.0);
    }

    #[test]
    fn optimal_trials_mixed_goals() {
        let metrics = vec![
            MetricInformation::maximize("acc"),
            MetricInformation {
                name: "latency".into(),
                goal: MetricGoal::Minimize,
                min_value: 0.0,
                max_value: f64::INFINITY,
            },
        ];
        let mk = |id, acc: f64, lat: f64| {
            let mut t = Trial::new(id, ParameterDict::new());
            t.state = TrialState::Completed;
            t.final_measurement =
                Some(Measurement::new(1).with_metric("acc", acc).with_metric("latency", lat));
            t
        };
        let trials = vec![
            mk(1, 0.9, 10.0), // optimal
            mk(2, 0.8, 5.0),  // optimal (faster)
            mk(3, 0.7, 20.0), // dominated by 1 and 2
        ];
        let front: Vec<u64> = optimal_trials(&trials, &metrics).iter().map(|t| t.id).collect();
        assert_eq!(front, vec![1, 2]);
    }

    #[test]
    fn prop_front_is_mutually_nondominated_and_complete() {
        prop::check("pareto front invariants", 100, |g| {
            let n = g.usize_range(1, 30);
            let k = g.usize_range(1, 4);
            let pts: Vec<Vec<f64>> = (0..n)
                .map(|_| (0..k).map(|_| g.f64_range(-5.0, 5.0)).collect())
                .collect();
            let front = pareto_front_indices(&pts);
            assert!(!front.is_empty());
            // No front member dominates another.
            for &i in &front {
                for &j in &front {
                    assert!(i == j || !dominates(&pts[i], &pts[j]));
                }
            }
            // Every non-front point is dominated by some front point.
            for i in 0..n {
                if !front.contains(&i) {
                    assert!(front.iter().any(|&j| dominates(&pts[j], &pts[i])));
                }
            }
            // Ranks agree with the front.
            let ranks = non_dominated_ranks(&pts);
            for i in 0..n {
                assert_eq!(ranks[i] == 0, front.contains(&i), "point {i}");
            }
        });
    }

    #[test]
    fn prop_hypervolume_monotone_in_points() {
        prop::check("hypervolume grows with added points", 50, |g| {
            let base: Vec<Vec<f64>> = (0..g.usize_range(1, 10))
                .map(|_| vec![g.f64_range(0.0, 5.0), g.f64_range(0.0, 5.0)])
                .collect();
            let hv1 = hypervolume_2d(&base, &[0.0, 0.0]);
            let mut more = base.clone();
            more.push(vec![g.f64_range(0.0, 5.0), g.f64_range(0.0, 5.0)]);
            let hv2 = hypervolume_2d(&more, &[0.0, 0.0]);
            assert!(hv2 >= hv1 - 1e-9, "hv shrank: {hv1} -> {hv2}");
        });
    }
}
