//! Namespaced key-value metadata (paper §4.1, §6.3).
//!
//! Metadata is not interpreted by the service; it is the channel through
//! which algorithms persist state (SerializableDesigner, Code Block 7),
//! users attach small blobs, and user code talks to policies. Namespaces
//! prevent key collisions between independent writers.

use std::collections::BTreeMap;

/// A two-level (namespace, key) -> bytes mapping.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Metadata {
    map: BTreeMap<(String, String), Vec<u8>>,
}

impl Metadata {
    pub fn new() -> Self {
        Self::default()
    }

    /// Store raw bytes under (namespace, key).
    pub fn put(&mut self, ns: &str, key: &str, value: impl Into<Vec<u8>>) {
        self.map.insert((ns.to_string(), key.to_string()), value.into());
    }

    /// Store a UTF-8 string (convenience for JSON designer state).
    pub fn put_str(&mut self, ns: &str, key: &str, value: &str) {
        self.put(ns, key, value.as_bytes().to_vec());
    }

    pub fn get(&self, ns: &str, key: &str) -> Option<&[u8]> {
        self.map
            .get(&(ns.to_string(), key.to_string()))
            .map(|v| v.as_slice())
    }

    pub fn get_str(&self, ns: &str, key: &str) -> Option<&str> {
        self.get(ns, key).and_then(|b| std::str::from_utf8(b).ok())
    }

    pub fn remove(&mut self, ns: &str, key: &str) -> Option<Vec<u8>> {
        self.map.remove(&(ns.to_string(), key.to_string()))
    }

    /// All (key, value) pairs within one namespace.
    pub fn ns<'a>(&'a self, ns: &'a str) -> impl Iterator<Item = (&'a str, &'a [u8])> + 'a {
        self.map
            .iter()
            .filter(move |((n, _), _)| n == ns)
            .map(|((_, k), v)| (k.as_str(), v.as_slice()))
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, &str, &[u8])> {
        self.map
            .iter()
            .map(|((n, k), v)| (n.as_str(), k.as_str(), v.as_slice()))
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Merge another metadata object in (overwrites on collision) —
    /// used when applying `UpdateMetadata` RPCs.
    pub fn merge_from(&mut self, other: &Metadata) {
        for ((n, k), v) in &other.map {
            self.map.insert((n.clone(), k.clone()), v.clone());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn namespaces_isolate_keys() {
        let mut m = Metadata::new();
        m.put_str("algo_a", "state", "a-state");
        m.put_str("algo_b", "state", "b-state");
        assert_eq!(m.get_str("algo_a", "state"), Some("a-state"));
        assert_eq!(m.get_str("algo_b", "state"), Some("b-state"));
        assert_eq!(m.len(), 2);
        let a_keys: Vec<_> = m.ns("algo_a").collect();
        assert_eq!(a_keys, vec![("state", "a-state".as_bytes())]);
    }

    #[test]
    fn binary_values_roundtrip() {
        let mut m = Metadata::new();
        m.put("", "blob", vec![0u8, 255, 7]);
        assert_eq!(m.get("", "blob"), Some(&[0u8, 255, 7][..]));
        assert_eq!(m.get_str("", "blob"), None); // not valid utf-8? 0,255,7 -> 255 invalid
    }

    #[test]
    fn merge_overwrites() {
        let mut a = Metadata::new();
        a.put_str("ns", "k", "old");
        let mut b = Metadata::new();
        b.put_str("ns", "k", "new");
        b.put_str("ns", "k2", "v2");
        a.merge_from(&b);
        assert_eq!(a.get_str("ns", "k"), Some("new"));
        assert_eq!(a.get_str("ns", "k2"), Some("v2"));
    }

    #[test]
    fn remove_works() {
        let mut m = Metadata::new();
        m.put_str("n", "k", "v");
        assert_eq!(m.remove("n", "k"), Some(b"v".to_vec()));
        assert!(m.get("n", "k").is_none());
        assert!(m.is_empty());
    }
}
