//! Search-space definition, validation, and sampling (paper §4.2).
//!
//! A search space is a forest of [`ParameterConfig`]s. Each numeric config
//! carries a [`ScaleType`]; each config may carry *conditional children*
//! that are only active when the parent takes particular values — the
//! paper's conditional-search mechanism (e.g. `model = {"linear", "dnn",
//! "random_forest"}`, each with its own subtree).

use super::parameter::{ParameterDict, ParameterValue};
use crate::util::rng::Pcg32;
use crate::wire::messages::ScaleType;

/// Rich parameter kind (PyVizier side of the proto's oneof).
#[derive(Debug, Clone, PartialEq)]
pub enum ParameterKind {
    /// Continuous `[min, max]`.
    Double { min: f64, max: f64 },
    /// Integers `[min, max]`.
    Integer { min: i64, max: i64 },
    /// Finite ordered set of reals.
    Discrete { values: Vec<f64> },
    /// Unordered strings.
    Categorical { values: Vec<String> },
}

/// Errors from search-space construction or trial validation.
#[derive(Debug, Clone, PartialEq)]
pub enum SpaceError {
    EmptyValues(String),
    BadBounds(String, f64, f64),
    BadLogBound(String, f64),
    ScaleOnNonNumeric(String),
    DuplicateName(String),
    UnknownParent(String),
    MissingParameter(String),
    UnexpectedParameter(String),
    OutOfRange(String, String),
    WrongType(String),
}

impl std::fmt::Display for SpaceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SpaceError::EmptyValues(p) => write!(f, "parameter {p:?}: empty value list"),
            SpaceError::BadBounds(p, lo, hi) => {
                write!(f, "parameter {p:?}: invalid bounds [{lo}, {hi}]")
            }
            SpaceError::BadLogBound(p, lo) => {
                write!(f, "parameter {p:?}: log scale requires positive lower bound, got {lo}")
            }
            SpaceError::ScaleOnNonNumeric(p) => {
                write!(f, "parameter {p:?}: scale type only applies to numeric parameters")
            }
            SpaceError::DuplicateName(p) => write!(f, "duplicate parameter name {p:?}"),
            SpaceError::UnknownParent(p) => write!(f, "unknown parent parameter {p:?}"),
            SpaceError::MissingParameter(p) => write!(f, "missing required parameter {p:?}"),
            SpaceError::UnexpectedParameter(p) => {
                write!(f, "unexpected parameter {p:?} (not active for this assignment)")
            }
            SpaceError::OutOfRange(p, v) => write!(f, "parameter {p:?}: value {v} out of range"),
            SpaceError::WrongType(p) => write!(f, "parameter {p:?}: wrong value type"),
        }
    }
}

impl std::error::Error for SpaceError {}

/// One parameter's specification, possibly with conditional children.
#[derive(Debug, Clone, PartialEq)]
pub struct ParameterConfig {
    pub name: String,
    pub kind: ParameterKind,
    pub scale: ScaleType,
    /// `(parent_values, child)`: child active iff the parent's assigned
    /// value matches one of `parent_values`.
    pub children: Vec<(Vec<ParameterValue>, ParameterConfig)>,
}

impl ParameterConfig {
    pub fn double(name: &str, min: f64, max: f64) -> Self {
        Self {
            name: name.to_string(),
            kind: ParameterKind::Double { min, max },
            scale: ScaleType::Linear,
            children: Vec::new(),
        }
    }

    pub fn integer(name: &str, min: i64, max: i64) -> Self {
        Self {
            name: name.to_string(),
            kind: ParameterKind::Integer { min, max },
            scale: ScaleType::Linear,
            children: Vec::new(),
        }
    }

    pub fn discrete(name: &str, mut values: Vec<f64>) -> Self {
        values.sort_by(|a, b| a.partial_cmp(b).unwrap());
        values.dedup();
        Self {
            name: name.to_string(),
            kind: ParameterKind::Discrete { values },
            scale: ScaleType::Linear,
            children: Vec::new(),
        }
    }

    pub fn categorical(name: &str, values: Vec<&str>) -> Self {
        Self {
            name: name.to_string(),
            kind: ParameterKind::Categorical {
                values: values.into_iter().map(|s| s.to_string()).collect(),
            },
            scale: ScaleType::Linear,
            children: Vec::new(),
        }
    }

    /// Set the scale type (numeric parameters only; checked by
    /// [`SearchSpace::validate_space`]).
    pub fn with_scale(mut self, scale: ScaleType) -> Self {
        self.scale = scale;
        self
    }

    /// Attach a conditional child active for the given parent values.
    pub fn with_child(
        mut self,
        parent_values: Vec<ParameterValue>,
        child: ParameterConfig,
    ) -> Self {
        self.children.push((parent_values, child));
        self
    }

    /// Is this config numeric (Double/Integer/Discrete)?
    pub fn is_numeric(&self) -> bool {
        !matches!(self.kind, ParameterKind::Categorical { .. })
    }

    /// Number of distinct values, or `None` for continuous parameters.
    pub fn cardinality(&self) -> Option<u64> {
        match &self.kind {
            ParameterKind::Double { .. } => None,
            ParameterKind::Integer { min, max } => Some((max - min + 1) as u64),
            ParameterKind::Discrete { values } => Some(values.len() as u64),
            ParameterKind::Categorical { values } => Some(values.len() as u64),
        }
    }

    /// Check a single value against this spec (ignores children).
    pub fn validate_value(&self, v: &ParameterValue) -> Result<(), SpaceError> {
        let name = self.name.clone();
        match (&self.kind, v) {
            (ParameterKind::Double { min, max }, val) => {
                let x = val.as_f64().ok_or(SpaceError::WrongType(name.clone()))?;
                if x < *min || x > *max || !x.is_finite() {
                    return Err(SpaceError::OutOfRange(name, x.to_string()));
                }
                Ok(())
            }
            (ParameterKind::Integer { min, max }, val) => {
                let x = val.as_i64().ok_or(SpaceError::WrongType(name.clone()))?;
                if x < *min || x > *max {
                    return Err(SpaceError::OutOfRange(name, x.to_string()));
                }
                Ok(())
            }
            (ParameterKind::Discrete { values }, val) => {
                let x = val.as_f64().ok_or(SpaceError::WrongType(name.clone()))?;
                if values.iter().any(|&d| d == x) {
                    Ok(())
                } else {
                    Err(SpaceError::OutOfRange(name, x.to_string()))
                }
            }
            (ParameterKind::Categorical { values }, ParameterValue::Str(s)) => {
                if values.iter().any(|c| c == s) {
                    Ok(())
                } else {
                    Err(SpaceError::OutOfRange(name, s.clone()))
                }
            }
            (ParameterKind::Categorical { .. }, _) => Err(SpaceError::WrongType(name)),
        }
    }

    /// Sample a value uniformly (in scaled space for numerics).
    pub fn sample_value(&self, rng: &mut Pcg32) -> ParameterValue {
        match &self.kind {
            ParameterKind::Double { min, max } => {
                let u = rng.f64();
                ParameterValue::F64(super::scaling::from_unit(self.scale, *min, *max, u))
            }
            ParameterKind::Integer { min, max } => ParameterValue::I64(rng.int_range(*min, *max)),
            ParameterKind::Discrete { values } => ParameterValue::F64(*rng.choose(values)),
            ParameterKind::Categorical { values } => {
                ParameterValue::Str(rng.choose(values).clone())
            }
        }
    }

    /// Project a (possibly out-of-range) value back into the feasible set.
    pub fn clamp_value(&self, v: &ParameterValue) -> ParameterValue {
        match (&self.kind, v) {
            (ParameterKind::Double { min, max }, val) => {
                let x = val.as_f64().unwrap_or(*min);
                ParameterValue::F64(x.clamp(*min, *max))
            }
            (ParameterKind::Integer { min, max }, val) => {
                let x = val.as_i64().unwrap_or(*min);
                ParameterValue::I64(x.clamp(*min, *max))
            }
            (ParameterKind::Discrete { values }, val) => {
                let x = val.as_f64().unwrap_or(values[0]);
                let nearest = values
                    .iter()
                    .copied()
                    .min_by(|a, b| (a - x).abs().partial_cmp(&(b - x).abs()).unwrap())
                    .unwrap();
                ParameterValue::F64(nearest)
            }
            (ParameterKind::Categorical { values }, ParameterValue::Str(s))
                if values.contains(s) =>
            {
                v.clone()
            }
            (ParameterKind::Categorical { values }, _) => ParameterValue::Str(values[0].clone()),
        }
    }

    fn check_spec(&self) -> Result<(), SpaceError> {
        match &self.kind {
            ParameterKind::Double { min, max } => {
                if !(min <= max) || !min.is_finite() || !max.is_finite() {
                    return Err(SpaceError::BadBounds(self.name.clone(), *min, *max));
                }
                if self.scale == ScaleType::Log && *min <= 0.0 {
                    return Err(SpaceError::BadLogBound(self.name.clone(), *min));
                }
            }
            ParameterKind::Integer { min, max } => {
                if min > max {
                    return Err(SpaceError::BadBounds(self.name.clone(), *min as f64, *max as f64));
                }
                if self.scale == ScaleType::Log && *min <= 0 {
                    return Err(SpaceError::BadLogBound(self.name.clone(), *min as f64));
                }
            }
            ParameterKind::Discrete { values } => {
                if values.is_empty() {
                    return Err(SpaceError::EmptyValues(self.name.clone()));
                }
            }
            ParameterKind::Categorical { values } => {
                if values.is_empty() {
                    return Err(SpaceError::EmptyValues(self.name.clone()));
                }
                if self.scale != ScaleType::Linear {
                    return Err(SpaceError::ScaleOnNonNumeric(self.name.clone()));
                }
            }
        }
        for (_, child) in &self.children {
            child.check_spec()?;
        }
        Ok(())
    }

    fn collect_names<'a>(&'a self, out: &mut Vec<&'a str>) {
        out.push(&self.name);
        for (_, child) in &self.children {
            child.collect_names(out);
        }
    }
}

/// The feasible space X of a study: a forest of parameter configs.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct SearchSpace {
    pub roots: Vec<ParameterConfig>,
}

impl SearchSpace {
    pub fn new() -> Self {
        Self::default()
    }

    // --- builder API (mirrors Code Block 1's `select_root().add_float`) ---

    pub fn add_float(&mut self, name: &str, min: f64, max: f64, scale: ScaleType) -> &mut Self {
        self.roots.push(ParameterConfig::double(name, min, max).with_scale(scale));
        self
    }

    pub fn add_int(&mut self, name: &str, min: i64, max: i64) -> &mut Self {
        self.roots.push(ParameterConfig::integer(name, min, max));
        self
    }

    pub fn add_discrete(&mut self, name: &str, values: Vec<f64>) -> &mut Self {
        self.roots.push(ParameterConfig::discrete(name, values));
        self
    }

    pub fn add_categorical(&mut self, name: &str, values: Vec<&str>) -> &mut Self {
        self.roots.push(ParameterConfig::categorical(name, values));
        self
    }

    /// Add a fully-built config (for conditional trees).
    pub fn add_param(&mut self, config: ParameterConfig) -> &mut Self {
        self.roots.push(config);
        self
    }

    /// Attach `child` under the (unique) parameter named `parent`, active
    /// for `parent_values`.
    pub fn add_conditional(
        &mut self,
        parent: &str,
        parent_values: Vec<ParameterValue>,
        child: ParameterConfig,
    ) -> Result<&mut Self, SpaceError> {
        fn attach(
            cfg: &mut ParameterConfig,
            parent: &str,
            pv: &[ParameterValue],
            child: &ParameterConfig,
        ) -> bool {
            if cfg.name == parent {
                cfg.children.push((pv.to_vec(), child.clone()));
                return true;
            }
            for (_, c) in cfg.children.iter_mut() {
                if attach(c, parent, pv, child) {
                    return true;
                }
            }
            false
        }
        for root in self.roots.iter_mut() {
            if attach(root, parent, &parent_values, &child) {
                return Ok(self);
            }
        }
        Err(SpaceError::UnknownParent(parent.to_string()))
    }

    /// Validate the space itself: bounds sane, names unique.
    pub fn validate_space(&self) -> Result<(), SpaceError> {
        let mut names = Vec::new();
        for root in &self.roots {
            root.check_spec()?;
            root.collect_names(&mut names);
        }
        let mut sorted = names.clone();
        sorted.sort_unstable();
        for w in sorted.windows(2) {
            if w[0] == w[1] {
                return Err(SpaceError::DuplicateName(w[0].to_string()));
            }
        }
        Ok(())
    }

    /// The configs *active* for a given assignment (parents first).
    pub fn active_configs<'a>(&'a self, params: &ParameterDict) -> Vec<&'a ParameterConfig> {
        let mut out = Vec::new();
        fn walk<'a>(
            cfg: &'a ParameterConfig,
            params: &ParameterDict,
            out: &mut Vec<&'a ParameterConfig>,
        ) {
            out.push(cfg);
            if let Some(v) = params.get(&cfg.name) {
                for (pv, child) in &cfg.children {
                    if pv.iter().any(|p| p.matches(v)) {
                        walk(child, params, out);
                    }
                }
            }
        }
        for root in &self.roots {
            walk(root, params, &mut out);
        }
        out
    }

    /// Validate a complete assignment: every active parameter present and
    /// in range; no extraneous parameters.
    pub fn validate(&self, params: &ParameterDict) -> Result<(), SpaceError> {
        let active = self.active_configs(params);
        for cfg in &active {
            match params.get(&cfg.name) {
                None => return Err(SpaceError::MissingParameter(cfg.name.clone())),
                Some(v) => cfg.validate_value(v)?,
            }
        }
        let active_names: Vec<&str> = active.iter().map(|c| c.name.as_str()).collect();
        for name in params.names() {
            if !active_names.contains(&name.as_str()) {
                return Err(SpaceError::UnexpectedParameter(name.clone()));
            }
        }
        Ok(())
    }

    /// Sample a feasible assignment (respecting conditionality and scaling).
    pub fn sample(&self, rng: &mut Pcg32) -> ParameterDict {
        self.assemble(|cfg| cfg.sample_value(rng))
    }

    /// Build a feasible assignment by asking `valuer` for each parameter's
    /// value, walking the conditional tree so only *active* children are
    /// included. Deterministic valuers (grid indices, Halton draws,
    /// designer mutations) get conditional-search support for free.
    pub fn assemble<F: FnMut(&ParameterConfig) -> ParameterValue>(
        &self,
        mut valuer: F,
    ) -> ParameterDict {
        let mut params = ParameterDict::new();
        fn walk<F: FnMut(&ParameterConfig) -> ParameterValue>(
            cfg: &ParameterConfig,
            valuer: &mut F,
            params: &mut ParameterDict,
        ) {
            let v = valuer(cfg);
            for (pv, child) in &cfg.children {
                if pv.iter().any(|p| p.matches(&v)) {
                    walk(child, valuer, params);
                }
            }
            params.set(cfg.name.clone(), v);
        }
        for root in &self.roots {
            walk(root, &mut valuer, &mut params);
        }
        params
    }

    /// All parameter configs, flattened (parents before children).
    pub fn all_configs(&self) -> Vec<&ParameterConfig> {
        let mut out = Vec::new();
        fn walk<'a>(cfg: &'a ParameterConfig, out: &mut Vec<&'a ParameterConfig>) {
            out.push(cfg);
            for (_, c) in &cfg.children {
                walk(c, out);
            }
        }
        for root in &self.roots {
            walk(root, &mut out);
        }
        out
    }

    /// Find a config by name anywhere in the forest.
    pub fn get(&self, name: &str) -> Option<&ParameterConfig> {
        self.all_configs().into_iter().find(|c| c.name == name)
    }

    /// Number of parameters (flattened).
    pub fn num_parameters(&self) -> usize {
        self.all_configs().len()
    }

    /// True if no parameter has conditional children.
    pub fn is_flat(&self) -> bool {
        self.all_configs().iter().all(|c| c.children.is_empty())
    }

    /// Total cardinality of the flattened space (None if any continuous).
    pub fn cardinality(&self) -> Option<u64> {
        self.all_configs()
            .iter()
            .try_fold(1u64, |acc, c| c.cardinality().map(|k| acc.saturating_mul(k)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's §4.2 example: tune model in {linear, dnn, random_forest},
    /// each with its own child parameters.
    pub fn conditional_space() -> SearchSpace {
        let mut space = SearchSpace::new();
        space.add_categorical("model", vec!["linear", "dnn", "random_forest"]);
        space
            .add_conditional(
                "model",
                vec!["dnn".into()],
                ParameterConfig::integer("num_layers", 1, 5),
            )
            .unwrap();
        space
            .add_conditional(
                "model",
                vec!["dnn".into(), "linear".into()],
                ParameterConfig::double("learning_rate", 1e-4, 1e-1).with_scale(ScaleType::Log),
            )
            .unwrap();
        space
            .add_conditional(
                "model",
                vec!["random_forest".into()],
                ParameterConfig::integer("num_trees", 10, 1000),
            )
            .unwrap();
        space
    }

    #[test]
    fn builder_and_space_validation() {
        let mut space = SearchSpace::new();
        space
            .add_float("lr", 1e-4, 1e-2, ScaleType::Log)
            .add_int("layers", 1, 5)
            .add_discrete("batch", vec![32.0, 16.0, 16.0, 64.0])
            .add_categorical("opt", vec!["sgd", "adam"]);
        space.validate_space().unwrap();
        assert_eq!(space.num_parameters(), 4);
        // Discrete values are sorted + deduped.
        match &space.get("batch").unwrap().kind {
            ParameterKind::Discrete { values } => assert_eq!(values, &vec![16.0, 32.0, 64.0]),
            _ => panic!(),
        }
    }

    #[test]
    fn invalid_spaces_rejected() {
        let mut s = SearchSpace::new();
        s.add_float("x", 2.0, 1.0, ScaleType::Linear);
        assert!(matches!(s.validate_space(), Err(SpaceError::BadBounds(..))));

        let mut s = SearchSpace::new();
        s.add_float("x", 0.0, 1.0, ScaleType::Log);
        assert!(matches!(s.validate_space(), Err(SpaceError::BadLogBound(..))));

        let mut s = SearchSpace::new();
        s.add_categorical("c", vec![]);
        assert!(matches!(s.validate_space(), Err(SpaceError::EmptyValues(..))));

        let mut s = SearchSpace::new();
        s.add_int("x", 0, 5).add_float("x", 0.0, 1.0, ScaleType::Linear);
        assert!(matches!(s.validate_space(), Err(SpaceError::DuplicateName(..))));
    }

    #[test]
    fn conditional_activation() {
        let space = conditional_space();
        space.validate_space().unwrap();

        let mut dnn = ParameterDict::new();
        dnn.set("model", "dnn").set("num_layers", 3i64).set("learning_rate", 0.01);
        space.validate(&dnn).unwrap();

        // random_forest must NOT carry dnn's params (paper: invariance).
        let mut rf = ParameterDict::new();
        rf.set("model", "random_forest").set("num_trees", 100i64);
        space.validate(&rf).unwrap();

        let mut bad = ParameterDict::new();
        bad.set("model", "random_forest")
            .set("num_trees", 100i64)
            .set("num_layers", 3i64);
        assert!(matches!(
            space.validate(&bad),
            Err(SpaceError::UnexpectedParameter(..))
        ));

        // Missing active child.
        let mut missing = ParameterDict::new();
        missing.set("model", "dnn").set("learning_rate", 0.01);
        assert!(matches!(
            space.validate(&missing),
            Err(SpaceError::MissingParameter(..))
        ));
    }

    #[test]
    fn sampling_always_valid() {
        let space = conditional_space();
        let mut rng = crate::util::rng::Pcg32::seeded(11);
        let mut saw_dnn = false;
        let mut saw_rf = false;
        for _ in 0..300 {
            let p = space.sample(&mut rng);
            space.validate(&p).unwrap();
            match p.get_str("model").unwrap() {
                "dnn" => saw_dnn = true,
                "random_forest" => saw_rf = true,
                _ => {}
            }
        }
        assert!(saw_dnn && saw_rf);
    }

    #[test]
    fn value_validation() {
        let cfg = ParameterConfig::double("x", 0.0, 1.0);
        assert!(cfg.validate_value(&ParameterValue::F64(0.5)).is_ok());
        assert!(cfg.validate_value(&ParameterValue::F64(1.5)).is_err());
        assert!(cfg.validate_value(&ParameterValue::F64(f64::NAN)).is_err());
        assert!(cfg.validate_value(&ParameterValue::Str("a".into())).is_err());

        let cfg = ParameterConfig::discrete("d", vec![1.0, 2.0]);
        assert!(cfg.validate_value(&ParameterValue::F64(2.0)).is_ok());
        assert!(cfg.validate_value(&ParameterValue::I64(2)).is_ok());
        assert!(cfg.validate_value(&ParameterValue::F64(1.5)).is_err());

        let cfg = ParameterConfig::categorical("c", vec!["a", "b"]);
        assert!(cfg.validate_value(&ParameterValue::Str("b".into())).is_ok());
        assert!(cfg.validate_value(&ParameterValue::Str("z".into())).is_err());
    }

    #[test]
    fn clamping_projects_to_feasible() {
        let cfg = ParameterConfig::double("x", 0.0, 1.0);
        assert_eq!(cfg.clamp_value(&ParameterValue::F64(7.0)), ParameterValue::F64(1.0));
        let cfg = ParameterConfig::discrete("d", vec![1.0, 4.0, 10.0]);
        assert_eq!(cfg.clamp_value(&ParameterValue::F64(5.5)), ParameterValue::F64(4.0));
        let cfg = ParameterConfig::integer("i", -3, 3);
        assert_eq!(cfg.clamp_value(&ParameterValue::I64(99)), ParameterValue::I64(3));
        let cfg = ParameterConfig::categorical("c", vec!["a", "b"]);
        assert_eq!(
            cfg.clamp_value(&ParameterValue::Str("zzz".into())),
            ParameterValue::Str("a".into())
        );
    }

    #[test]
    fn cardinality() {
        let mut s = SearchSpace::new();
        s.add_int("a", 1, 4).add_categorical("b", vec!["x", "y", "z"]);
        assert_eq!(s.cardinality(), Some(12));
        s.add_float("c", 0.0, 1.0, ScaleType::Linear);
        assert_eq!(s.cardinality(), None);
    }

    #[test]
    fn unknown_parent_rejected() {
        let mut s = SearchSpace::new();
        s.add_int("a", 1, 4);
        let err = s
            .add_conditional("nope", vec![ParameterValue::I64(1)], ParameterConfig::integer("b", 0, 1))
            .unwrap_err();
        assert!(matches!(err, SpaceError::UnknownParent(..)));
    }
}
