//! Parameter scaling (paper §4.2): maps between a parameter's native range
//! and the unit interval the optimizer works in. Log scaling gives the
//! subrange [0.001, 0.01] the same optimizer attention as [1, 10].

use crate::wire::messages::ScaleType;

/// Map a value in `[min, max]` to `[0, 1]` under the given scale.
pub fn to_unit(scale: ScaleType, min: f64, max: f64, v: f64) -> f64 {
    let v = v.clamp(min, max);
    if max <= min {
        return 0.0;
    }
    match scale {
        ScaleType::Linear => (v - min) / (max - min),
        ScaleType::Log => {
            assert!(min > 0.0, "log scale requires positive bounds");
            (v.ln() - min.ln()) / (max.ln() - min.ln())
        }
        // Attention concentrated near the MAX end: mirror, log, mirror.
        ScaleType::ReverseLog => {
            let span = max - min;
            let m = (max - v) / span; // 0 at max, 1 at min
            1.0 - ((1.0 + m * span).ln() / (1.0 + span).ln())
        }
    }
}

/// Inverse of [`to_unit`].
pub fn from_unit(scale: ScaleType, min: f64, max: f64, u: f64) -> f64 {
    let u = u.clamp(0.0, 1.0);
    if max <= min {
        return min;
    }
    let v = match scale {
        ScaleType::Linear => min + u * (max - min),
        ScaleType::Log => (min.ln() + u * (max.ln() - min.ln())).exp(),
        ScaleType::ReverseLog => {
            let span = max - min;
            let m = (((1.0 - u) * (1.0 + span).ln()).exp() - 1.0) / span;
            max - m * span
        }
    };
    v.clamp(min, max)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(scale: ScaleType, min: f64, max: f64) {
        for i in 0..=20 {
            let u = i as f64 / 20.0;
            let v = from_unit(scale, min, max, u);
            assert!((min..=max).contains(&v), "{scale:?} {u} -> {v}");
            let u2 = to_unit(scale, min, max, v);
            assert!((u - u2).abs() < 1e-9, "{scale:?}: {u} -> {v} -> {u2}");
        }
    }

    #[test]
    fn linear_roundtrip() {
        roundtrip(ScaleType::Linear, -5.0, 10.0);
    }

    #[test]
    fn log_roundtrip() {
        roundtrip(ScaleType::Log, 1e-4, 1e2);
    }

    #[test]
    fn reverse_log_roundtrip() {
        roundtrip(ScaleType::ReverseLog, 0.0, 1.0);
        roundtrip(ScaleType::ReverseLog, 2.0, 50.0);
    }

    #[test]
    fn endpoints_map_exactly() {
        for scale in [ScaleType::Linear, ScaleType::Log, ScaleType::ReverseLog] {
            let (min, max) = (0.5, 8.0);
            assert!((to_unit(scale, min, max, min) - 0.0).abs() < 1e-12);
            assert!((to_unit(scale, min, max, max) - 1.0).abs() < 1e-12);
            assert!((from_unit(scale, min, max, 0.0) - min).abs() < 1e-12);
            assert!((from_unit(scale, min, max, 1.0) - max).abs() < 1e-12);
        }
    }

    #[test]
    fn log_scale_equalizes_decades() {
        // Paper's example: [0.001, 0.01] should get the same unit-space
        // width as [1, 10] within [0.001, 10].
        let (min, max) = (0.001, 10.0);
        let w1 = to_unit(ScaleType::Log, min, max, 0.01) - to_unit(ScaleType::Log, min, max, 0.001);
        let w2 = to_unit(ScaleType::Log, min, max, 10.0) - to_unit(ScaleType::Log, min, max, 1.0);
        assert!((w1 - w2).abs() < 1e-9, "{w1} vs {w2}");
        // Under linear scaling they are wildly different.
        let l1 = to_unit(ScaleType::Linear, min, max, 0.01) - to_unit(ScaleType::Linear, min, max, 0.001);
        let l2 = to_unit(ScaleType::Linear, min, max, 10.0) - to_unit(ScaleType::Linear, min, max, 1.0);
        assert!(l2 / l1 > 100.0);
    }

    #[test]
    fn reverse_log_concentrates_near_max() {
        // Half of unit space should map closer to max than linear would.
        let v = from_unit(ScaleType::ReverseLog, 0.0, 1.0, 0.5);
        assert!(v > 0.5, "reverse-log midpoint {v} should exceed 0.5");
    }

    #[test]
    fn out_of_range_inputs_clamp() {
        assert_eq!(to_unit(ScaleType::Linear, 0.0, 1.0, 5.0), 1.0);
        assert_eq!(to_unit(ScaleType::Linear, 0.0, 1.0, -5.0), 0.0);
        assert_eq!(from_unit(ScaleType::Linear, 0.0, 1.0, 2.0), 1.0);
        assert_eq!(from_unit(ScaleType::Linear, 0.0, 1.0, -1.0), 0.0);
    }
}
