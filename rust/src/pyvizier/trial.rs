//! Trials and measurements (PyVizier side; paper §4.1, Figure 3).

use super::metadata::Metadata;
use super::parameter::ParameterDict;
use std::collections::BTreeMap;

pub use crate::wire::messages::TrialState;

/// One evaluation of the objective(s), possibly intermediate.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Measurement {
    pub step: i64,
    pub elapsed_secs: f64,
    pub metrics: BTreeMap<String, f64>,
}

impl Measurement {
    pub fn new(step: i64) -> Self {
        Self {
            step,
            ..Default::default()
        }
    }

    pub fn with_metric(mut self, name: &str, value: f64) -> Self {
        self.metrics.insert(name.to_string(), value);
        self
    }

    pub fn get(&self, name: &str) -> Option<f64> {
        self.metrics.get(name).copied()
    }
}

/// A suggestion produced by a policy, before it is registered as a trial.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct TrialSuggestion {
    pub parameters: ParameterDict,
    pub metadata: Metadata,
}

impl TrialSuggestion {
    pub fn new(parameters: ParameterDict) -> Self {
        Self {
            parameters,
            metadata: Metadata::new(),
        }
    }
}

/// A trial: the input x plus (eventually) the objective value(s) f(x).
#[derive(Debug, Clone, PartialEq)]
pub struct Trial {
    pub id: u64,
    pub state: TrialState,
    pub parameters: ParameterDict,
    pub measurements: Vec<Measurement>,
    pub final_measurement: Option<Measurement>,
    /// Worker this trial is assigned to (paper §5 client_id semantics).
    pub client_id: String,
    pub infeasibility_reason: Option<String>,
    pub metadata: Metadata,
    pub created_ms: u64,
    pub completed_ms: u64,
}

impl Default for Trial {
    fn default() -> Self {
        Self {
            id: 0,
            state: TrialState::Requested,
            parameters: ParameterDict::new(),
            measurements: Vec::new(),
            final_measurement: None,
            client_id: String::new(),
            infeasibility_reason: None,
            metadata: Metadata::new(),
            created_ms: 0,
            completed_ms: 0,
        }
    }
}

impl Trial {
    pub fn new(id: u64, parameters: ParameterDict) -> Self {
        Self {
            id,
            parameters,
            ..Default::default()
        }
    }

    pub fn is_completed(&self) -> bool {
        matches!(self.state, TrialState::Completed | TrialState::Infeasible)
    }

    pub fn is_active(&self) -> bool {
        matches!(self.state, TrialState::Active | TrialState::Requested)
    }

    pub fn is_feasible_completed(&self) -> bool {
        self.state == TrialState::Completed && self.infeasibility_reason.is_none()
    }

    /// The final value of a metric, falling back to the last intermediate
    /// measurement if no final measurement was reported.
    pub fn final_metric(&self, name: &str) -> Option<f64> {
        if let Some(fm) = &self.final_measurement {
            if let Some(v) = fm.get(name) {
                return Some(v);
            }
        }
        self.measurements.iter().rev().find_map(|m| m.get(name))
    }

    /// Best intermediate value of `name` seen so far (max if `maximize`).
    pub fn best_intermediate(&self, name: &str, maximize: bool) -> Option<f64> {
        let it = self.measurements.iter().filter_map(|m| m.get(name));
        if maximize {
            it.fold(None, |acc: Option<f64>, v| Some(acc.map_or(v, |a| a.max(v))))
        } else {
            it.fold(None, |acc: Option<f64>, v| Some(acc.map_or(v, |a| a.min(v))))
        }
    }

    /// Running average of intermediate values up to and including `step`
    /// (the Median stopping rule's notion of 'performance', Appendix B.1).
    pub fn running_average_until(&self, name: &str, step: i64) -> Option<f64> {
        let vals: Vec<f64> = self
            .measurements
            .iter()
            .filter(|m| m.step <= step)
            .filter_map(|m| m.get(name))
            .collect();
        if vals.is_empty() {
            None
        } else {
            Some(vals.iter().sum::<f64>() / vals.len() as f64)
        }
    }

    pub fn last_step(&self) -> Option<i64> {
        self.measurements.iter().map(|m| m.step).max()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn curve_trial() -> Trial {
        let mut t = Trial::new(1, ParameterDict::new());
        for (step, acc) in [(1, 0.2), (2, 0.5), (3, 0.4), (4, 0.8)] {
            t.measurements.push(Measurement::new(step).with_metric("acc", acc));
        }
        t
    }

    #[test]
    fn final_metric_prefers_final_measurement() {
        let mut t = curve_trial();
        assert_eq!(t.final_metric("acc"), Some(0.8)); // falls back to last
        t.final_measurement = Some(Measurement::new(5).with_metric("acc", 0.9));
        assert_eq!(t.final_metric("acc"), Some(0.9));
        assert_eq!(t.final_metric("missing"), None);
    }

    #[test]
    fn best_intermediate_directions() {
        let t = curve_trial();
        assert_eq!(t.best_intermediate("acc", true), Some(0.8));
        assert_eq!(t.best_intermediate("acc", false), Some(0.2));
        assert_eq!(t.best_intermediate("nope", true), None);
    }

    #[test]
    fn running_average() {
        let t = curve_trial();
        assert!((t.running_average_until("acc", 2).unwrap() - 0.35).abs() < 1e-12);
        assert!((t.running_average_until("acc", 4).unwrap() - 0.475).abs() < 1e-12);
        assert_eq!(t.running_average_until("acc", 0), None);
    }

    #[test]
    fn state_helpers() {
        let mut t = Trial::new(1, ParameterDict::new());
        assert!(t.is_active());
        assert!(!t.is_completed());
        t.state = TrialState::Completed;
        assert!(t.is_completed());
        assert!(t.is_feasible_completed());
        t.infeasibility_reason = Some("nan".into());
        assert!(!t.is_feasible_completed());
        t.state = TrialState::Infeasible;
        assert!(t.is_completed());
    }

    #[test]
    fn last_step() {
        assert_eq!(curve_trial().last_step(), Some(4));
        assert_eq!(Trial::new(1, ParameterDict::new()).last_step(), None);
    }
}
