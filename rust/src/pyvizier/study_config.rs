//! Study configuration (PyVizier `StudyConfig` + `MetricInformation`,
//! Table 2; paper §4.1).

use super::search_space::SearchSpace;
use super::trial::Trial;
use super::Metadata;
use crate::wire::messages::{MetricGoal, ObservationNoise, StoppingConfig};

/// Information about one objective metric.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricInformation {
    pub name: String,
    pub goal: MetricGoal,
    pub min_value: f64,
    pub max_value: f64,
}

impl MetricInformation {
    pub fn maximize(name: &str) -> Self {
        Self {
            name: name.to_string(),
            goal: MetricGoal::Maximize,
            min_value: f64::NEG_INFINITY,
            max_value: f64::INFINITY,
        }
    }

    pub fn minimize(name: &str) -> Self {
        Self {
            name: name.to_string(),
            goal: MetricGoal::Minimize,
            min_value: f64::NEG_INFINITY,
            max_value: f64::INFINITY,
        }
    }

    pub fn with_range(mut self, min: f64, max: f64) -> Self {
        self.min_value = min;
        self.max_value = max;
        self
    }

    /// Is `a` strictly better than `b` for this metric?
    pub fn better(&self, a: f64, b: f64) -> bool {
        match self.goal {
            MetricGoal::Maximize => a > b,
            MetricGoal::Minimize => a < b,
        }
    }

    /// Sign-normalized value: larger is always better.
    pub fn maximization_value(&self, v: f64) -> f64 {
        match self.goal {
            MetricGoal::Maximize => v,
            MetricGoal::Minimize => -v,
        }
    }
}

/// The suggestion algorithm for a study. `Custom` routes to a
/// user-registered Pythia policy by name (paper §6).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum Algorithm {
    RandomSearch,
    GridSearch,
    QuasiRandomSearch,
    HillClimb,
    RegularizedEvolution,
    Nsga2,
    HarmonySearch,
    Firefly,
    GpBandit,
    Custom(String),
}

impl Algorithm {
    pub fn as_str(&self) -> &str {
        match self {
            Algorithm::RandomSearch => "RANDOM_SEARCH",
            Algorithm::GridSearch => "GRID_SEARCH",
            Algorithm::QuasiRandomSearch => "QUASI_RANDOM_SEARCH",
            Algorithm::HillClimb => "HILL_CLIMB",
            Algorithm::RegularizedEvolution => "REGULARIZED_EVOLUTION",
            Algorithm::Nsga2 => "NSGA2",
            Algorithm::HarmonySearch => "HARMONY_SEARCH",
            Algorithm::Firefly => "FIREFLY",
            Algorithm::GpBandit => "GP_BANDIT",
            Algorithm::Custom(s) => s,
        }
    }

    pub fn from_str(s: &str) -> Algorithm {
        match s {
            "RANDOM_SEARCH" | "" => Algorithm::RandomSearch,
            "GRID_SEARCH" => Algorithm::GridSearch,
            "QUASI_RANDOM_SEARCH" => Algorithm::QuasiRandomSearch,
            "HILL_CLIMB" => Algorithm::HillClimb,
            "REGULARIZED_EVOLUTION" => Algorithm::RegularizedEvolution,
            "NSGA2" => Algorithm::Nsga2,
            "HARMONY_SEARCH" => Algorithm::HarmonySearch,
            "FIREFLY" => Algorithm::Firefly,
            "GP_BANDIT" => Algorithm::GpBandit,
            other => Algorithm::Custom(other.to_string()),
        }
    }
}

/// Full study configuration (search space + metrics + algorithm + knobs).
#[derive(Debug, Clone, PartialEq)]
pub struct StudyConfig {
    pub display_name: String,
    pub search_space: SearchSpace,
    pub metrics: Vec<MetricInformation>,
    pub algorithm: Algorithm,
    pub observation_noise: ObservationNoise,
    pub stopping: StoppingConfig,
    pub metadata: Metadata,
    /// Seed for deterministic policies (0 = derive from study name).
    pub seed: u64,
}

impl Default for StudyConfig {
    fn default() -> Self {
        Self {
            display_name: String::new(),
            search_space: SearchSpace::new(),
            metrics: Vec::new(),
            algorithm: Algorithm::RandomSearch,
            observation_noise: ObservationNoise::Unspecified,
            stopping: StoppingConfig::default(),
            metadata: Metadata::new(),
            seed: 0,
        }
    }
}

/// Errors from study-config validation.
#[derive(Debug, Clone, PartialEq)]
pub enum ConfigError {
    NoMetrics,
    DuplicateMetric(String),
    Space(super::search_space::SpaceError),
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::NoMetrics => write!(f, "study must define at least one metric"),
            ConfigError::DuplicateMetric(m) => write!(f, "duplicate metric name {m:?}"),
            ConfigError::Space(e) => write!(f, "search space error: {e}"),
        }
    }
}

impl std::error::Error for ConfigError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ConfigError::Space(e) => Some(e),
            _ => None,
        }
    }
}

impl From<super::search_space::SpaceError> for ConfigError {
    fn from(e: super::search_space::SpaceError) -> Self {
        ConfigError::Space(e)
    }
}

impl StudyConfig {
    pub fn new(display_name: &str) -> Self {
        Self {
            display_name: display_name.to_string(),
            ..Default::default()
        }
    }

    pub fn add_metric(&mut self, m: MetricInformation) -> &mut Self {
        self.metrics.push(m);
        self
    }

    pub fn is_single_objective(&self) -> bool {
        self.metrics.len() == 1
    }

    pub fn metric(&self, name: &str) -> Option<&MetricInformation> {
        self.metrics.iter().find(|m| m.name == name)
    }

    /// The single objective metric (panics on multi-objective studies;
    /// policies that support only single-objective call this).
    pub fn single_objective(&self) -> &MetricInformation {
        assert!(
            self.is_single_objective(),
            "study has {} metrics; expected exactly one",
            self.metrics.len()
        );
        &self.metrics[0]
    }

    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.metrics.is_empty() {
            return Err(ConfigError::NoMetrics);
        }
        let mut names: Vec<&str> = self.metrics.iter().map(|m| m.name.as_str()).collect();
        names.sort_unstable();
        for w in names.windows(2) {
            if w[0] == w[1] {
                return Err(ConfigError::DuplicateMetric(w[0].to_string()));
            }
        }
        self.search_space.validate_space()?;
        Ok(())
    }

    /// Is trial `a` strictly better than `b` on the single objective?
    /// Infeasible/incomplete trials are never better.
    pub fn trial_better(&self, a: &Trial, b: &Trial) -> bool {
        let m = self.single_objective();
        match (a.final_metric(&m.name), b.final_metric(&m.name)) {
            (Some(va), Some(vb)) => {
                a.is_feasible_completed() && (!b.is_feasible_completed() || m.better(va, vb))
            }
            (Some(_), None) => a.is_feasible_completed(),
            _ => false,
        }
    }

    /// The best completed feasible trial on the single objective.
    pub fn best_trial<'a>(&self, trials: impl IntoIterator<Item = &'a Trial>) -> Option<&'a Trial> {
        let m = self.single_objective();
        trials
            .into_iter()
            .filter(|t| t.is_feasible_completed() && t.final_metric(&m.name).is_some())
            .max_by(|a, b| {
                let va = m.maximization_value(a.final_metric(&m.name).unwrap());
                let vb = m.maximization_value(b.final_metric(&m.name).unwrap());
                va.partial_cmp(&vb).unwrap_or(std::cmp::Ordering::Equal)
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pyvizier::trial::{Measurement, TrialState};
    use crate::pyvizier::ParameterDict;
    use crate::wire::messages::ScaleType;

    fn config() -> StudyConfig {
        let mut c = StudyConfig::new("test");
        c.search_space.add_float("lr", 1e-4, 1e-2, ScaleType::Log);
        c.add_metric(MetricInformation::maximize("accuracy").with_range(0.0, 1.0));
        c
    }

    fn completed(id: u64, acc: f64) -> Trial {
        let mut t = Trial::new(id, ParameterDict::new());
        t.state = TrialState::Completed;
        t.final_measurement = Some(Measurement::new(1).with_metric("accuracy", acc));
        t
    }

    #[test]
    fn validation() {
        config().validate().unwrap();
        let mut c = StudyConfig::new("no-metrics");
        assert_eq!(c.validate(), Err(ConfigError::NoMetrics));
        c.add_metric(MetricInformation::maximize("a"));
        c.add_metric(MetricInformation::minimize("a"));
        assert!(matches!(c.validate(), Err(ConfigError::DuplicateMetric(_))));
    }

    #[test]
    fn metric_direction() {
        let max = MetricInformation::maximize("m");
        assert!(max.better(2.0, 1.0));
        assert!(!max.better(1.0, 2.0));
        let min = MetricInformation::minimize("m");
        assert!(min.better(1.0, 2.0));
        assert_eq!(min.maximization_value(3.0), -3.0);
    }

    #[test]
    fn best_trial_maximize() {
        let c = config();
        let trials = vec![completed(1, 0.3), completed(2, 0.9), completed(3, 0.5)];
        assert_eq!(c.best_trial(&trials).unwrap().id, 2);
    }

    #[test]
    fn best_trial_skips_infeasible_and_active() {
        let c = config();
        let mut infeasible = completed(1, 0.99);
        infeasible.infeasibility_reason = Some("broken".into());
        let mut active = completed(2, 0.95);
        active.state = TrialState::Active;
        let ok = completed(3, 0.5);
        let trials = vec![infeasible, active, ok];
        assert_eq!(c.best_trial(&trials).unwrap().id, 3);
    }

    #[test]
    fn trial_better_handles_missing() {
        let c = config();
        let a = completed(1, 0.9);
        let empty = Trial::new(2, ParameterDict::new());
        assert!(c.trial_better(&a, &empty));
        assert!(!c.trial_better(&empty, &a));
    }

    #[test]
    fn algorithm_string_roundtrip() {
        for a in [
            Algorithm::RandomSearch,
            Algorithm::GridSearch,
            Algorithm::QuasiRandomSearch,
            Algorithm::HillClimb,
            Algorithm::RegularizedEvolution,
            Algorithm::Nsga2,
            Algorithm::HarmonySearch,
            Algorithm::Firefly,
            Algorithm::GpBandit,
            Algorithm::Custom("MY_POLICY".into()),
        ] {
            assert_eq!(Algorithm::from_str(a.as_str()), a);
        }
        assert_eq!(Algorithm::from_str(""), Algorithm::RandomSearch);
    }
}
