//! Parameter values and dictionaries (PyVizier `ParameterValue` /
//! `ParameterDict`, paper Code Block 6).

use std::collections::BTreeMap;
use std::fmt;

/// A single parameter's assigned value.
#[derive(Debug, Clone, PartialEq)]
pub enum ParameterValue {
    F64(f64),
    I64(i64),
    Str(String),
    Bool(bool),
}

impl ParameterValue {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            ParameterValue::F64(v) => Some(*v),
            ParameterValue::I64(v) => Some(*v as f64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            ParameterValue::I64(v) => Some(*v),
            ParameterValue::F64(v) if v.fract() == 0.0 => Some(*v as i64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            ParameterValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            ParameterValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Equality as used for conditional-parent matching: numeric values
    /// compare across F64/I64; strings and bools compare exactly.
    pub fn matches(&self, other: &ParameterValue) -> bool {
        match (self, other) {
            (ParameterValue::Str(a), ParameterValue::Str(b)) => a == b,
            (ParameterValue::Bool(a), ParameterValue::Bool(b)) => a == b,
            (a, b) => match (a.as_f64(), b.as_f64()) {
                (Some(x), Some(y)) => x == y,
                _ => false,
            },
        }
    }
}

impl fmt::Display for ParameterValue {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ParameterValue::F64(v) => write!(f, "{v}"),
            ParameterValue::I64(v) => write!(f, "{v}"),
            ParameterValue::Str(v) => write!(f, "{v}"),
            ParameterValue::Bool(v) => write!(f, "{v}"),
        }
    }
}

impl From<f64> for ParameterValue {
    fn from(v: f64) -> Self {
        ParameterValue::F64(v)
    }
}
impl From<i64> for ParameterValue {
    fn from(v: i64) -> Self {
        ParameterValue::I64(v)
    }
}
impl From<&str> for ParameterValue {
    fn from(v: &str) -> Self {
        ParameterValue::Str(v.to_string())
    }
}
impl From<String> for ParameterValue {
    fn from(v: String) -> Self {
        ParameterValue::Str(v)
    }
}
impl From<bool> for ParameterValue {
    fn from(v: bool) -> Self {
        ParameterValue::Bool(v)
    }
}

/// An ordered name -> value mapping for one trial's parameters.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ParameterDict {
    map: BTreeMap<String, ParameterValue>,
}

impl ParameterDict {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn set(&mut self, name: impl Into<String>, value: impl Into<ParameterValue>) -> &mut Self {
        self.map.insert(name.into(), value.into());
        self
    }

    pub fn get(&self, name: &str) -> Option<&ParameterValue> {
        self.map.get(name)
    }

    pub fn get_f64(&self, name: &str) -> Option<f64> {
        self.get(name).and_then(|v| v.as_f64())
    }

    pub fn get_i64(&self, name: &str) -> Option<i64> {
        self.get(name).and_then(|v| v.as_i64())
    }

    pub fn get_str(&self, name: &str) -> Option<&str> {
        self.get(name).and_then(|v| v.as_str())
    }

    pub fn contains(&self, name: &str) -> bool {
        self.map.contains_key(name)
    }

    pub fn remove(&mut self, name: &str) -> Option<ParameterValue> {
        self.map.remove(name)
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &ParameterValue)> {
        self.map.iter()
    }

    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.map.keys()
    }
}

impl FromIterator<(String, ParameterValue)> for ParameterDict {
    fn from_iter<T: IntoIterator<Item = (String, ParameterValue)>>(iter: T) -> Self {
        Self {
            map: iter.into_iter().collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversions() {
        assert_eq!(ParameterValue::from(1.5).as_f64(), Some(1.5));
        assert_eq!(ParameterValue::from(3i64).as_f64(), Some(3.0));
        assert_eq!(ParameterValue::from(3.0).as_i64(), Some(3));
        assert_eq!(ParameterValue::from(3.5).as_i64(), None);
        assert_eq!(ParameterValue::from("vgg").as_str(), Some("vgg"));
        assert_eq!(ParameterValue::from(true).as_bool(), Some(true));
        assert_eq!(ParameterValue::from("x").as_f64(), None);
    }

    #[test]
    fn matches_cross_numeric() {
        assert!(ParameterValue::F64(2.0).matches(&ParameterValue::I64(2)));
        assert!(!ParameterValue::F64(2.5).matches(&ParameterValue::I64(2)));
        assert!(ParameterValue::Str("a".into()).matches(&ParameterValue::Str("a".into())));
        assert!(!ParameterValue::Str("a".into()).matches(&ParameterValue::F64(1.0)));
    }

    #[test]
    fn dict_ops() {
        let mut d = ParameterDict::new();
        d.set("learning_rate", 0.4).set("model_type", "vgg").set("layers", 3i64);
        assert_eq!(d.get_f64("learning_rate"), Some(0.4));
        assert_eq!(d.get_str("model_type"), Some("vgg"));
        assert_eq!(d.get_i64("layers"), Some(3));
        assert_eq!(d.len(), 3);
        assert!(d.contains("model_type"));
        d.remove("model_type");
        assert!(!d.contains("model_type"));
        // Deterministic iteration order (BTreeMap).
        let names: Vec<&String> = d.names().collect();
        assert_eq!(names, vec!["layers", "learning_rate"]);
    }
}
