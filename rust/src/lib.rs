//! # OSS Vizier (reproduction)
//!
//! A Rust + JAX + Pallas reproduction of *Open Source Vizier: Distributed
//! Infrastructure and API for Reliable and Flexible Blackbox Optimization*
//! (Song et al., AutoML-Conf 2022): a distributed blackbox-optimization
//! **service** with durable operations, parallel fault-tolerant clients,
//! a Pythia developer API for algorithms, and a GP-bandit backend whose
//! numeric hot path is AOT-compiled from JAX/Pallas and executed from Rust
//! via PJRT. See DESIGN.md for the full system inventory.

#![deny(unsafe_op_in_unsafe_fn)]

pub mod benchmarks;
pub mod client;
pub mod datastore;
pub mod policies;
pub mod pythia;
pub mod pyvizier;
pub mod runtime;
pub mod service;
pub mod stopping;
pub mod testing;
pub mod util;
pub mod wire;
