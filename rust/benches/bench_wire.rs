//! C-WIRE: wire-codec performance — encode/decode throughput for the
//! messages that dominate traffic (trials with measurements, study specs,
//! operations). The protobuf-equivalent layer must never be the service
//! bottleneck.

use ossvizier::util::benchkit::{bench, finish, note, section};
use ossvizier::wire::codec::{decode, encode};
use ossvizier::wire::messages::*;

fn big_trial(id: u64, n_measurements: usize) -> TrialProto {
    TrialProto {
        id,
        state: TrialState::Completed,
        parameters: (0..8)
            .map(|i| TrialParameter {
                parameter_id: format!("param_{i}"),
                value: if i % 2 == 0 {
                    ParamValue::F64(0.123456789 * i as f64)
                } else {
                    ParamValue::Str(format!("categorical_value_{i}"))
                },
            })
            .collect(),
        final_measurement: Some(Measurement {
            step_count: n_measurements as i64,
            elapsed_secs: 12.5,
            metrics: vec![Metric { metric_id: "accuracy".into(), value: 0.93 }],
        }),
        measurements: (0..n_measurements as i64)
            .map(|s| Measurement {
                step_count: s,
                elapsed_secs: s as f64,
                metrics: vec![Metric { metric_id: "accuracy".into(), value: 0.5 }],
            })
            .collect(),
        client_id: "worker-17".into(),
        infeasibility_reason: String::new(),
        metadata: vec![MetadataItem {
            namespace: "designer.reg_evo".into(),
            key: "population".into(),
            value: vec![b'x'; 2048],
        }],
        created_ms: 1,
        completed_ms: 2,
    }
}

fn main() {
    section("C-WIRE: encode/decode throughput");
    let trial = big_trial(1, 20);
    let bytes = encode(&trial);
    note(&format!("trial size on the wire: {} bytes", bytes.len()));

    bench("encode trial (20 measurements)", || {
        std::hint::black_box(encode(&trial));
    });
    bench("decode trial (20 measurements)", || {
        let t: TrialProto = decode(&bytes).unwrap();
        std::hint::black_box(t);
    });

    let batch = ListTrialsResponse {
        trials: (0..500).map(|i| big_trial(i, 20)).collect(),
        next_page_token: String::new(),
    };
    let batch_bytes = encode(&batch);
    note(&format!(
        "500-trial ListTrials response: {:.1} KiB",
        batch_bytes.len() as f64 / 1024.0
    ));
    let r = bench("encode 500-trial response", || {
        std::hint::black_box(encode(&batch));
    });
    note(&format!(
        "encode bandwidth: {:.0} MiB/s",
        batch_bytes.len() as f64 / (r.mean_us() / 1e6) / (1024.0 * 1024.0)
    ));
    let r = bench("decode 500-trial response", || {
        let b: ListTrialsResponse = decode(&batch_bytes).unwrap();
        std::hint::black_box(b);
    });
    note(&format!(
        "decode bandwidth: {:.0} MiB/s",
        batch_bytes.len() as f64 / (r.mean_us() / 1e6) / (1024.0 * 1024.0)
    ));

    // Study spec with a conditional tree.
    let mut spec = StudySpecProto::default();
    for i in 0..20 {
        spec.parameters.push(ParameterSpecProto {
            parameter_id: format!("p{i}"),
            kind: ParameterKind::Double { min: 0.0, max: 1.0 },
            scale_type: ScaleType::Log,
            conditional_children: vec![ConditionalParameterSpec {
                parent_values: ParentValues { values: vec![ParamValue::F64(0.5)] },
                spec: ParameterSpecProto {
                    parameter_id: format!("c{i}"),
                    kind: ParameterKind::Categorical { values: vec!["a".into(), "b".into()] },
                    scale_type: ScaleType::Linear,
                    conditional_children: vec![],
                },
            }],
        });
    }
    let spec_bytes = encode(&spec);
    bench("encode study spec (20 conditional params)", || {
        std::hint::black_box(encode(&spec));
    });
    bench("decode study spec (20 conditional params)", || {
        let s: StudySpecProto = decode(&spec_bytes).unwrap();
        std::hint::black_box(s);
    });
    finish("WIRE");
}
