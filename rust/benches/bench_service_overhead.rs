//! C-OVH (paper §8's stated limitation): "if evaluating f(x) is very
//! cheap and fast (e.g. milliseconds), then the OSS Vizier service itself
//! may dominate the overall cost and speed." This bench measures the
//! per-trial service overhead and locates the crossover where f(x) cost
//! stops being dominated by it.

use ossvizier::client::{LocalTransport, TcpTransport, VizierClient};
use ossvizier::pyvizier::{Algorithm, Measurement, MetricInformation, StudyConfig};
use ossvizier::service::{in_memory_service, VizierServer};
use ossvizier::util::benchkit::{finish, note, section};
use ossvizier::util::time::Stopwatch;
use ossvizier::wire::messages::ScaleType;
use std::time::Duration;

fn config(name: &str) -> StudyConfig {
    let mut c = StudyConfig::new(name);
    c.search_space.add_float("x", 0.0, 1.0, ScaleType::Linear);
    c.add_metric(MetricInformation::minimize("v"));
    c.algorithm = Algorithm::RandomSearch;
    c
}

fn per_trial_overhead(mut client: VizierClient, trials: usize, f_cost: Duration) -> f64 {
    let sw = Stopwatch::start();
    for _ in 0..trials {
        let t = client.get_suggestions(1).unwrap().remove(0);
        if !f_cost.is_zero() {
            std::thread::sleep(f_cost);
        }
        let x = t.parameters.get_f64("x").unwrap();
        client
            .complete_trial(t.id, Some(&Measurement::new(1).with_metric("v", x)))
            .unwrap();
    }
    sw.elapsed().as_secs_f64() * 1e3 / trials as f64
}

fn main() {
    section("C-OVH: per-trial service cost (suggest op + complete), f(x) = free");
    let local = {
        let service = in_memory_service(4);
        let c = VizierClient::load_or_create_study(
            Box::new(LocalTransport::new(service)),
            "ovh-local",
            &config("ovh-local"),
            "w",
        )
        .unwrap();
        let ms = per_trial_overhead(c, 300, Duration::ZERO);
        note(&format!("in-process transport: {ms:.3} ms/trial"));
        ms
    };
    let tcp = {
        let service = in_memory_service(4);
        let server = VizierServer::start(service, "127.0.0.1:0").unwrap();
        let addr = server.local_addr().to_string();
        let c = VizierClient::load_or_create_study(
            Box::new(TcpTransport::connect(&addr).unwrap()),
            "ovh-tcp",
            &config("ovh-tcp"),
            "w",
        )
        .unwrap();
        let ms = per_trial_overhead(c, 300, Duration::ZERO);
        note(&format!("tcp transport:        {ms:.3} ms/trial"));
        server.shutdown();
        ms
    };

    section("C-OVH: overhead share vs f(x) cost (tcp)");
    for &f_ms in &[0.0f64, 1.0, 5.0, 20.0, 100.0] {
        let service = in_memory_service(4);
        let server = VizierServer::start(service, "127.0.0.1:0").unwrap();
        let addr = server.local_addr().to_string();
        let c = VizierClient::load_or_create_study(
            Box::new(TcpTransport::connect(&addr).unwrap()),
            "ovh-sweep",
            &config("ovh-sweep"),
            "w",
        )
        .unwrap();
        let trials = if f_ms >= 20.0 { 40 } else { 150 };
        let total = per_trial_overhead(c, trials, Duration::from_secs_f64(f_ms / 1e3));
        let share = 100.0 * (total - f_ms).max(0.0) / total;
        println!(
            "f(x) = {f_ms:>6.1} ms -> {total:>7.2} ms/trial, service share {share:>5.1}%{}",
            if share > 50.0 { "  <- service dominates (paper's unsuitable regime)" } else { "" }
        );
        server.shutdown();
    }
    note(&format!(
        "crossover: service stops dominating once f(x) >~ {:.1} ms (tcp) / {:.1} ms (local)",
        tcp, local
    ));
    finish("SERVICE_OVERHEAD");
}
