//! C-WAL-ROTATE / C-WAL-SHARD: the segmented WAL under load.
//!
//! * **C-WAL-ROTATE** — commit latency while a compaction runs. The
//!   single-file layout's `compact()` stalls every commit for the whole
//!   snapshot (the deprecated baseline); the segmented layout's
//!   background compactor must keep the commit path flowing, so the max
//!   and p99 commit latency observed during compaction stay bounded
//!   instead of tracking the snapshot duration.
//! * **C-WAL-SHARD** — durable multi-shard write throughput with
//!   per-shard commit lanes vs the serialized-apply baseline
//!   (`WalOptions::serial_apply`), which funnels every in-memory apply
//!   through one lane the way the old group-commit lock did.
//!
//! `OSSVIZIER_SOAK=1` scales the fleet up for the nightly job. Artifacts
//! land in `BENCH_WAL_ROTATE.json` for the compare-benches CI gate.

use ossvizier::datastore::wal::{WalDatastore, WalOptions};
use ossvizier::datastore::Datastore;
use ossvizier::util::benchkit::{bench, check, check_strict, finish, note, section};
use ossvizier::util::time::Stopwatch;
use ossvizier::wire::messages::{MetadataItem, StudyProto, TrialProto};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

fn soak() -> bool {
    std::env::var_os("OSSVIZIER_SOAK").is_some()
}

fn tmp(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("ossvizier-bench-walrot-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d.join("wal")
}

fn study(name: &str) -> StudyProto {
    StudyProto { display_name: name.into(), ..Default::default() }
}

/// A trial with a ~512 B payload so encode + apply cost is realistic
/// (metadata-carrying trials are the common case for stateful policies).
fn heavy_trial() -> TrialProto {
    TrialProto {
        metadata: vec![MetadataItem {
            namespace: "bench".into(),
            key: "payload".into(),
            value: vec![0u8; 512],
        }],
        ..Default::default()
    }
}

fn percentile(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = ((sorted.len() as f64 * q).ceil() as usize).clamp(1, sorted.len()) - 1;
    sorted[idx]
}

struct StallProbe {
    p99_us: u64,
    max_us: u64,
    compact_ms: f64,
    commits_during: u64,
    total_trials: u64,
}

/// One writer thread commits continuously while `compact()` fires from
/// the main thread; every commit latency observed while the compaction
/// is in flight is recorded. In the single-file layout the first commit
/// issued after `compact()` starts blocks on the commit gate for the
/// entire snapshot, so `max_us` there *is* the stall.
fn compaction_stall(opts: WalOptions, tag: &str, preload: usize) -> StallProbe {
    let ds = Arc::new(WalDatastore::open_with_options(tmp(tag), opts).unwrap());
    let s = ds.create_study(study("rot")).unwrap();
    // Preload real state: the snapshot (and therefore the single-file
    // stall) scales with it.
    for _ in 0..preload {
        ds.create_trial(&s.name, heavy_trial()).unwrap();
    }
    let stop = Arc::new(AtomicBool::new(false));
    let compacting = Arc::new(AtomicBool::new(false));
    let writer = {
        let ds = Arc::clone(&ds);
        let name = s.name.clone();
        let stop = Arc::clone(&stop);
        let compacting = Arc::clone(&compacting);
        std::thread::spawn(move || {
            let mut during: Vec<u64> = Vec::new();
            while !stop.load(Ordering::Relaxed) {
                let tagged = compacting.load(Ordering::Relaxed);
                let sw = Stopwatch::start();
                ds.create_trial(&name, TrialProto::default()).unwrap();
                // A commit that *started* during the compaction window
                // counts even if the window closed while it was blocked —
                // that is exactly the stall being measured.
                if tagged || compacting.load(Ordering::Relaxed) {
                    during.push(sw.elapsed_micros());
                }
            }
            during
        })
    };
    std::thread::sleep(std::time::Duration::from_millis(30)); // steady state
    compacting.store(true, Ordering::Relaxed);
    let sw = Stopwatch::start();
    ds.compact().unwrap();
    let compact_ms = sw.elapsed_millis_f64();
    compacting.store(false, Ordering::Relaxed);
    std::thread::sleep(std::time::Duration::from_millis(10));
    stop.store(true, Ordering::Relaxed);
    let mut during = writer.join().unwrap();
    during.sort_unstable();
    let total_trials = ds.trial_count(&s.name).unwrap() as u64;
    StallProbe {
        p99_us: percentile(&during, 0.99),
        max_us: during.last().copied().unwrap_or(0),
        compact_ms,
        commits_during: during.len() as u64,
        total_trials,
    }
}

fn bench_rotate() {
    let preload = if soak() { 50_000 } else { 20_000 };
    section("C-WAL-ROTATE: commit latency during compaction");
    let single = compaction_stall(WalOptions::default(), "stall-single", preload);
    let seg = compaction_stall(
        WalOptions { segment_bytes: Some(1 << 20), ..WalOptions::default() },
        "stall-seg",
        preload,
    );
    note(&format!(
        "single-file (stall baseline): compact {:.2} ms, {} commits in window, \
         p99 {} us, max {} us",
        single.compact_ms, single.commits_during, single.p99_us, single.max_us
    ));
    note(&format!(
        "segmented (background):       compact {:.2} ms, {} commits in window, \
         p99 {} us, max {} us",
        seg.compact_ms, seg.commits_during, seg.p99_us, seg.max_us
    ));
    // Correctness is unconditional: every acknowledged commit survived
    // in both layouts.
    check_strict(
        "wal-rotate-no-lost-commits",
        single.total_trials > preload as u64 && seg.total_trials > preload as u64,
        &format!(
            "trials after run: single {} / segmented {} (preload {preload})",
            single.total_trials, seg.total_trials
        ),
    );
    // The headline: the segmented compactor must not stall commits. The
    // baseline's max latency IS the snapshot stall; segmented stays an
    // order of magnitude under it (allow 50% + a 5 ms floor for runner
    // noise).
    let bound_us = ((single.max_us as f64) * 0.5).max(5_000.0);
    check(
        "wal-rotate-commit-stall-bounded",
        (seg.max_us as f64) <= bound_us && seg.p99_us <= single.max_us.max(5_000),
        &format!(
            "segmented max {} us / p99 {} us vs single-file stall max {} us (bound {bound_us:.0} us)",
            seg.max_us, seg.p99_us, single.max_us
        ),
    );
    check(
        "wal-rotate-commits-flow-during-compaction",
        seg.commits_during >= single.commits_during,
        &format!(
            "commits completed in the compaction window: segmented {} vs single-file {}",
            seg.commits_during, single.commits_during
        ),
    );

    section("C-WAL-ROTATE: steady-state durable commit cost");
    {
        let ds = WalDatastore::open_with_options(tmp("steady-single"), WalOptions::default()).unwrap();
        let s = ds.create_study(study("st")).unwrap();
        bench("single-file: create_trial (group commit)", || {
            ds.create_trial(&s.name, TrialProto::default()).unwrap();
        });
    }
    {
        let ds = WalDatastore::open_with_options(
            tmp("steady-seg"),
            WalOptions { segment_bytes: Some(1 << 20), ..WalOptions::default() },
        )
        .unwrap();
        let s = ds.create_study(study("st")).unwrap();
        bench("segmented: create_trial (group commit)", || {
            ds.create_trial(&s.name, TrialProto::default()).unwrap();
        });
    }
}

fn shard_run(serial_apply: bool, tag: &str, threads: usize, per_thread: usize) -> (f64, u64, u64) {
    let opts = WalOptions {
        serial_apply,
        segment_bytes: Some(8 << 20),
        ..WalOptions::default()
    };
    let ds = Arc::new(WalDatastore::open_with_options(tmp(tag), opts).unwrap());
    let studies: Vec<String> = (0..threads)
        .map(|i| ds.create_study(study(&format!("sh{i}"))).unwrap().name)
        .collect();
    let sw = Stopwatch::start();
    let handles: Vec<_> = studies
        .into_iter()
        .map(|name| {
            let ds = Arc::clone(&ds);
            std::thread::spawn(move || {
                for _ in 0..per_thread {
                    ds.create_trial(&name, heavy_trial()).unwrap();
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let ms = sw.elapsed_millis_f64();
    (ms, ds.records_flushed(), ds.batches_flushed())
}

fn bench_shard() {
    let threads = 8;
    let per_thread = if soak() { 4_000 } else { 1_500 };
    let ops = (threads * per_thread) as f64;
    section("C-WAL-SHARD: durable multi-shard apply, 8 writers x distinct studies");
    let (serial_ms, s_recs, s_batches) = shard_run(true, "shard-serial", threads, per_thread);
    let (lanes_ms, l_recs, l_batches) = shard_run(false, "shard-lanes", threads, per_thread);
    note(&format!(
        "serialized apply (1 lane):   {serial_ms:>8.2} ms  ({:>9.0} ops/s, {s_recs} recs / {s_batches} batches)",
        ops / (serial_ms / 1e3)
    ));
    note(&format!(
        "per-shard lanes (16 lanes):  {lanes_ms:>8.2} ms  ({:>9.0} ops/s, {l_recs} recs / {l_batches} batches)  speedup {:.2}x",
        ops / (lanes_ms / 1e3),
        serial_ms / lanes_ms
    ));
    check(
        "wal-shard-lanes-vs-serialized-apply",
        lanes_ms <= serial_ms * 1.15,
        &format!(
            "per-shard lanes must not lose to the serialized-apply baseline \
             ({lanes_ms:.2} ms vs {serial_ms:.2} ms)"
        ),
    );
    // Durability accounting is layout-independent: every record flushed.
    check_strict(
        "wal-shard-records-flushed",
        s_recs == ops as u64 + threads as u64 && l_recs == ops as u64 + threads as u64,
        &format!("records flushed serial {s_recs} / lanes {l_recs}, expected {}", ops as u64 + threads as u64),
    );
}

fn main() {
    bench_rotate();
    bench_shard();
    finish("WAL_ROTATE");
}
