//! C-PAR: service RPC throughput under concurrent clients over real TCP
//! (paper §2.1: "scale up to thousands of concurrent users, and
//! continuously process user requests without interruptions").

use ossvizier::client::{TcpTransport, VizierClient};
use ossvizier::pyvizier::{Algorithm, Measurement, MetricInformation, StudyConfig};
use ossvizier::service::{in_memory_service, VizierServer};
use ossvizier::util::benchkit::{finish, note, section};
use ossvizier::util::time::Stopwatch;
use ossvizier::wire::messages::ScaleType;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

fn config() -> StudyConfig {
    let mut c = StudyConfig::new("throughput");
    c.search_space.add_float("x", 0.0, 1.0, ScaleType::Linear);
    c.add_metric(MetricInformation::minimize("v"));
    c.algorithm = Algorithm::RandomSearch;
    c
}

fn main() {
    section("C-PAR: end-to-end trial throughput vs #concurrent TCP clients");
    for &clients in &[1usize, 2, 4, 8, 16, 32] {
        let service = in_memory_service(16);
        let server = VizierServer::start(service, "127.0.0.1:0").unwrap();
        let addr = server.local_addr().to_string();
        let cfg = config();
        let total = Arc::new(AtomicU64::new(0));
        let budget_per_client = 600 / clients;
        let sw = Stopwatch::start();
        let handles: Vec<_> = (0..clients)
            .map(|i| {
                let addr = addr.clone();
                let cfg = cfg.clone();
                let total = Arc::clone(&total);
                std::thread::spawn(move || {
                    let t = Box::new(TcpTransport::connect(&addr).unwrap());
                    let mut c = VizierClient::load_or_create_study(
                        t,
                        "throughput",
                        &cfg,
                        &format!("c{i}"),
                    )
                    .unwrap();
                    for _ in 0..budget_per_client {
                        let trial = c.get_suggestions(1).unwrap().remove(0);
                        c.complete_trial(
                            trial.id,
                            Some(&Measurement::new(1).with_metric("v", 0.5)),
                        )
                        .unwrap();
                        total.fetch_add(1, Ordering::Relaxed);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let secs = sw.elapsed().as_secs_f64();
        let n = total.load(Ordering::Relaxed);
        println!(
            "{clients:>3} clients: {n:>6} trials in {secs:>6.2}s = {:>8.1} trials/s \
             ({:.2} ms/trial incl. suggest-op poll)",
            n as f64 / secs,
            secs * 1e3 / n as f64
        );
        server.shutdown();
    }

    section("raw RPC throughput (Ping) on one connection");
    let service = in_memory_service(4);
    let server = VizierServer::start(service, "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();
    let t = Box::new(TcpTransport::connect(&addr).unwrap());
    let mut c = VizierClient::for_study(t, "none", "p");
    let sw = Stopwatch::start();
    let n = 20_000;
    for _ in 0..n {
        c.ping().unwrap();
    }
    let secs = sw.elapsed().as_secs_f64();
    note(&format!(
        "{n} pings in {secs:.2}s = {:.0} rpc/s ({:.1} us/rpc round-trip)",
        n as f64 / secs,
        secs * 1e6 / n as f64
    ));
    server.shutdown();
    finish("SERVICE_THROUGHPUT");
}
