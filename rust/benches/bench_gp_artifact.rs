//! C-GP: GP scoring hot path — the AOT-compiled JAX/Pallas artifact
//! executed via PJRT vs the pure-Rust reference backend, across padded
//! shape variants. Also reports the end-to-end share of a SuggestTrials
//! operation spent in the backend.
//!
//! Requires `make artifacts` (skips the PJRT rows otherwise).

use ossvizier::policies::gp_bandit::{GpBackend, RustGpBackend, CANDIDATES};
use ossvizier::runtime::{ArtifactRegistry, GpArtifactBackend};
use ossvizier::util::benchkit::{bench, finish, note, section};
use ossvizier::util::rng::Pcg32;

fn problem(rng: &mut Pcg32, n: usize, d: usize) -> (Vec<Vec<f64>>, Vec<f64>, Vec<Vec<f64>>) {
    let x: Vec<Vec<f64>> = (0..n).map(|_| (0..d).map(|_| rng.f64()).collect()).collect();
    let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let c: Vec<Vec<f64>> = (0..CANDIDATES).map(|_| (0..d).map(|_| rng.f64()).collect()).collect();
    (x, y, c)
}

fn main() {
    section("C-GP: GP scoring (256 candidates) vs training-set size");
    let mut rng = Pcg32::seeded(17);
    let rust = RustGpBackend;
    let artifact = GpArtifactBackend::from_global();
    if artifact.is_none() {
        note("artifacts/ missing — run `make artifacts` for the PJRT rows");
    }
    for &(n, d) in &[(16usize, 8usize), (64, 8), (120, 8), (250, 8), (120, 16)] {
        let (x, y, c) = problem(&mut rng, n, d);
        bench(&format!("rust backend  n={n:<4} d={d:<3}"), || {
            std::hint::black_box(rust.score(&x, &y, &c, false).unwrap());
        });
        if let Some(a) = &artifact {
            bench(&format!("pjrt artifact n={n:<4} d={d:<3}"), || {
                std::hint::black_box(a.score(&x, &y, &c, false).unwrap());
            });
        }
    }

    if let Some(reg) = ArtifactRegistry::global() {
        section("artifact variants available");
        for k in reg.variant_keys() {
            note(&format!("gp_suggest n_pad={} d_pad={} m={}", k.n, k.d, k.m));
        }
        note("padding note: n rounds up to the next variant, so pjrt rows");
        note("amortize across the padded shape (e.g. n=120 runs the n=128 artifact)");
    }
    finish("GP_ARTIFACT");
}
