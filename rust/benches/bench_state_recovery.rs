//! HEADLINE BENCH (C-STATE, paper §6.3): suggestion cost of a designer
//! *with* metadata state saving (O(new trials) per operation) vs the
//! naive stateless wrapper that rebuilds from all trials (O(n)).
//!
//! The paper's claim: state saving "can reduce the database work by
//! orders of magnitude relative to loading all the Trials". Expected
//! shape: stateless latency grows linearly in #completed trials; the
//! metadata-backed designer stays flat.

use ossvizier::datastore::memory::InMemoryDatastore;
use ossvizier::datastore::Datastore;
use ossvizier::policies::reg_evolution::RegularizedEvolution;
use ossvizier::policies::test_objective_score;
use ossvizier::pythia::designer::{DesignerPolicy, StatelessDesignerPolicy};
use ossvizier::pythia::policy::{Policy, SuggestRequest};
use ossvizier::pythia::supporter::{DatastoreSupporter, PolicySupporter};
use ossvizier::pyvizier::{converters, Algorithm, Measurement, MetricInformation, StudyConfig, Trial, TrialState};
use ossvizier::util::benchkit::{bench, note, section};
use ossvizier::util::rng::Pcg32;
use ossvizier::wire::messages::{ScaleType, StudyProto};
use std::sync::Arc;

fn setup(n_trials: usize) -> (Arc<DatastoreSupporter>, String, StudyConfig) {
    let mut config = StudyConfig::new("state-recovery");
    config
        .search_space
        .add_float("lr", 1e-4, 1e-1, ScaleType::Log)
        .add_int("layers", 1, 5);
    config.add_metric(MetricInformation::maximize("score"));
    config.algorithm = Algorithm::RegularizedEvolution;
    config.seed = 3;
    let ds = Arc::new(InMemoryDatastore::new());
    let study = ds
        .create_study(StudyProto {
            display_name: "state-recovery".into(),
            spec: converters::study_config_to_proto(&config),
            ..Default::default()
        })
        .unwrap();
    let mut rng = Pcg32::seeded(9);
    for _ in 0..n_trials {
        let params = config.search_space.sample(&mut rng);
        let score = test_objective_score(&params);
        let mut t = Trial::new(0, params);
        t.state = TrialState::Completed;
        t.final_measurement = Some(Measurement::new(1).with_metric("score", score));
        ds.create_trial(&study.name, converters::trial_to_proto(&t)).unwrap();
    }
    let sup = Arc::new(DatastoreSupporter::new(ds as Arc<dyn Datastore>));
    (sup, study.name, config)
}

fn run_policy(policy: &mut dyn Policy, sup: &DatastoreSupporter, study: &str, config: &StudyConfig) {
    let req = SuggestRequest {
        study_name: study.to_string(),
        study_config: config.clone(),
        count: 1,
        client_id: "bench".into(),
    };
    let d = policy.suggest(&req, sup).expect("suggest");
    if let Some(md) = &d.study_metadata {
        sup.update_study_metadata(study, md).unwrap();
    }
}

fn main() {
    section("C-STATE: designer state recovery, suggest latency vs #completed trials");
    let sizes = [50usize, 200, 1000, 4000];
    let mut stateless_means = Vec::new();
    let mut stateful_means = Vec::new();
    for &n in &sizes {
        let (sup, study, config) = setup(n);
        // Warm the metadata state once so the stateful path measures the
        // steady state (restore + read 0 new trials + dump).
        run_policy(&mut DesignerPolicy::<RegularizedEvolution>::new(), &sup, &study, &config);

        let r1 = bench(&format!("stateless rebuild         n={n:<5}"), || {
            run_policy(
                &mut StatelessDesignerPolicy::<RegularizedEvolution>::default(),
                &sup,
                &study,
                &config,
            );
        });
        let r2 = bench(&format!("metadata state (paper)    n={n:<5}"), || {
            run_policy(&mut DesignerPolicy::<RegularizedEvolution>::new(), &sup, &study, &config);
        });
        stateless_means.push(r1.mean_us());
        stateful_means.push(r2.mean_us());
    }
    section("shape check");
    let growth_stateless = stateless_means.last().unwrap() / stateless_means[0];
    let growth_stateful = stateful_means.last().unwrap() / stateful_means[0];
    note(&format!(
        "stateless grows {growth_stateless:.1}x from n=50 to n=4000; stateful grows {growth_stateful:.1}x"
    ));
    note(&format!(
        "speedup at n=4000: {:.1}x",
        stateless_means.last().unwrap() / stateful_means.last().unwrap()
    ));
    assert!(
        growth_stateless > growth_stateful * 2.0,
        "stateless must scale worse than metadata-state"
    );
}
