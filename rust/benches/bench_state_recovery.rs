//! HEADLINE BENCH (C-STATE, paper §6.3): suggestion cost of a designer
//! *with* metadata state saving (O(new trials) per operation) vs the
//! naive stateless wrapper that rebuilds from all trials (O(n)).
//!
//! The paper's claim: state saving "can reduce the database work by
//! orders of magnitude relative to loading all the Trials". Expected
//! shape: stateless latency grows linearly in #completed trials; the
//! metadata-backed designer stays flat.

use ossvizier::datastore::memory::InMemoryDatastore;
use ossvizier::datastore::Datastore;
use ossvizier::policies::reg_evolution::RegularizedEvolution;
use ossvizier::policies::test_objective_score;
use ossvizier::pythia::designer::{DesignerPolicy, StatelessDesignerPolicy};
use ossvizier::pythia::policy::{Policy, SuggestRequest};
use ossvizier::pythia::supporter::{DatastoreSupporter, PolicySupporter};
use ossvizier::pyvizier::{converters, Algorithm, Measurement, MetricInformation, StudyConfig, Trial, TrialState};
use ossvizier::util::benchkit::{bench, finish, note, section};
use ossvizier::util::rng::Pcg32;
use ossvizier::wire::messages::{ScaleType, StudyProto};
use std::sync::Arc;

fn setup(n_trials: usize) -> (Arc<DatastoreSupporter>, String, StudyConfig) {
    let mut config = StudyConfig::new("state-recovery");
    config
        .search_space
        .add_float("lr", 1e-4, 1e-1, ScaleType::Log)
        .add_int("layers", 1, 5);
    config.add_metric(MetricInformation::maximize("score"));
    config.algorithm = Algorithm::RegularizedEvolution;
    config.seed = 3;
    let ds = Arc::new(InMemoryDatastore::new());
    let study = ds
        .create_study(StudyProto {
            display_name: "state-recovery".into(),
            spec: converters::study_config_to_proto(&config),
            ..Default::default()
        })
        .unwrap();
    let mut rng = Pcg32::seeded(9);
    for _ in 0..n_trials {
        let params = config.search_space.sample(&mut rng);
        let score = test_objective_score(&params);
        let mut t = Trial::new(0, params);
        t.state = TrialState::Completed;
        t.final_measurement = Some(Measurement::new(1).with_metric("score", score));
        ds.create_trial(&study.name, converters::trial_to_proto(&t)).unwrap();
    }
    let sup = Arc::new(DatastoreSupporter::new(ds as Arc<dyn Datastore>));
    (sup, study.name, config)
}

fn run_policy(policy: &mut dyn Policy, sup: &DatastoreSupporter, study: &str, config: &StudyConfig) {
    let req = SuggestRequest::single(study, config.clone(), "bench", 1);
    let d = policy.suggest(&req, sup).expect("suggest");
    if !d.metadata_delta.on_study.is_empty() {
        sup.update_study_metadata(study, &d.metadata_delta.on_study).unwrap();
    }
}

fn main() {
    section("C-STATE: designer state recovery, suggest latency vs #completed trials");
    let sizes = [50usize, 200, 1000, 4000];
    let mut stateless_means = Vec::new();
    let mut stateful_means = Vec::new();
    for &n in &sizes {
        let (sup, study, config) = setup(n);
        // Warm the metadata state once so the stateful path measures the
        // steady state (restore + read 0 new trials + dump).
        run_policy(&mut DesignerPolicy::<RegularizedEvolution>::new(), &sup, &study, &config);

        let r1 = bench(&format!("stateless rebuild         n={n:<5}"), || {
            run_policy(
                &mut StatelessDesignerPolicy::<RegularizedEvolution>::default(),
                &sup,
                &study,
                &config,
            );
        });
        let r2 = bench(&format!("metadata state (paper)    n={n:<5}"), || {
            run_policy(&mut DesignerPolicy::<RegularizedEvolution>::new(), &sup, &study, &config);
        });
        stateless_means.push(r1.mean_us());
        stateful_means.push(r2.mean_us());
    }
    section("shape check");
    let growth_stateless = stateless_means.last().unwrap() / stateless_means[0];
    let growth_stateful = stateful_means.last().unwrap() / stateful_means[0];
    note(&format!(
        "stateless grows {growth_stateless:.1}x from n=50 to n=4000; stateful grows {growth_stateful:.1}x"
    ));
    note(&format!(
        "speedup at n=4000: {:.1}x",
        stateless_means.last().unwrap() / stateful_means.last().unwrap()
    ));
    assert!(
        growth_stateless > growth_stateful * 2.0,
        "stateless must scale worse than metadata-state"
    );

    // ------------------------------------------------------------------
    // C-STATE-MT: state recovery of a *contended* log — 8 writer threads
    // generate trials through the group-commit WAL, then the log is
    // replayed as a fresh server would at startup (§3.2). Verifies that
    // batched commits keep recovery exact under parallel load, and
    // reports both write throughput and replay time.
    // ------------------------------------------------------------------
    use ossvizier::datastore::wal::{WalDatastore, WalOptions};
    use ossvizier::util::time::Stopwatch;
    use ossvizier::wire::messages::TrialProto;

    section("C-STATE-MT: concurrent writers -> WAL replay");
    const THREADS: usize = 8;
    const PER_THREAD: usize = 2_000;
    let dir = std::env::temp_dir().join(format!(
        "ossvizier-bench-state-mt-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("store.wal");
    {
        let ds = Arc::new(
            WalDatastore::open_with_options(&path, WalOptions::default()).unwrap(),
        );
        let studies: Vec<String> = (0..THREADS)
            .map(|i| {
                ds.create_study(StudyProto {
                    display_name: format!("mt{i}"),
                    ..Default::default()
                })
                .unwrap()
                .name
            })
            .collect();
        let sw = Stopwatch::start();
        let handles: Vec<_> = studies
            .into_iter()
            .map(|name| {
                let ds = Arc::clone(&ds);
                std::thread::spawn(move || {
                    for _ in 0..PER_THREAD {
                        ds.create_trial(&name, TrialProto::default()).unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let ms = sw.elapsed_millis_f64();
        let total = (THREADS * PER_THREAD) as f64;
        note(&format!(
            "write: {total:.0} trials from {THREADS} threads in {ms:.2} ms \
             ({:.0} ops/s, {} records in {} flush batches)",
            total / (ms / 1e3),
            ds.records_flushed(),
            ds.batches_flushed()
        ));
    }
    let size_mb = std::fs::metadata(&path).unwrap().len() as f64 / 1e6;
    let sw = Stopwatch::start();
    let recovered = WalDatastore::open(&path).unwrap();
    let ms = sw.elapsed_millis_f64();
    let mut total = 0usize;
    for s in recovered.list_studies().unwrap() {
        total += recovered.trial_count(&s.name).unwrap();
    }
    assert_eq!(
        total,
        THREADS * PER_THREAD,
        "replay must recover every acknowledged trial"
    );
    note(&format!(
        "replay: {total} trials across {THREADS} studies ({size_mb:.2} MB log) in {ms:.2} ms"
    ));
    finish("STATE_RECOVERY");
}
