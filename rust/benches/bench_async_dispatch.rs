//! C-ASYNC-DISPATCH: the completion-driven operation scheduler. With
//! `--policy-workers P` and a gated (never-returning until released)
//! policy, one server must hold **more than 3×P in-flight suggest
//! operations** — the policy pool bounds concurrent GP fits, not
//! accepted work — while every waiting client is parked in a server-side
//! `WaitOperation` long-poll:
//!
//! * front-end threads stay at `workers + 2` (procfs), i.e. parked
//!   waiters cost connections, not threads;
//! * after the gate opens, every client completes through exactly one
//!   `WaitOperation` round-trip — zero `GetOperation` busy-poll traffic
//!   from the new client path;
//! * wakeup latency (operation completion -> parked client woken) is
//!   reported from the `wait_wakeup` histogram.
//!
//! `OSSVIZIER_SOAK=1` scales the policy pool and client fleet up.
//! Results land in `BENCH_async_dispatch.json` at the repo root.

use ossvizier::client::{TcpTransport, VizierClient};
use ossvizier::datastore::memory::InMemoryDatastore;
use ossvizier::datastore::Datastore;
use ossvizier::pythia::policy::{Policy, PolicyError, SuggestDecision, SuggestRequest};
use ossvizier::pythia::supporter::PolicySupporter;
use ossvizier::pyvizier::{Algorithm, MetricInformation, ScaleType, StudyConfig, TrialSuggestion};
use ossvizier::service::{build_service, ServerOptions, VizierServer};
use ossvizier::testing::procfs::threads_with_prefix;
use ossvizier::util::benchkit::{check_strict, finish, note, section};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

const FE_WORKERS: usize = 4;

fn soak() -> bool {
    std::env::var_os("OSSVIZIER_SOAK").is_some()
}

#[derive(Default)]
struct Gate {
    open: Mutex<bool>,
    cv: Condvar,
}

impl Gate {
    fn release(&self) {
        *self.open.lock().unwrap() = true;
        self.cv.notify_all();
    }

    fn wait(&self) {
        let mut open = self.open.lock().unwrap();
        while !*open {
            open = self.cv.wait(open).unwrap();
        }
    }
}

/// Every invocation blocks until the gate opens: policy workers are all
/// pinned, so accepted-but-unserved operations pile up behind them.
struct SlowPolicy {
    gate: Arc<Gate>,
    invocations: Arc<AtomicUsize>,
}

impl Policy for SlowPolicy {
    fn suggest(
        &mut self,
        req: &SuggestRequest,
        _s: &dyn PolicySupporter,
    ) -> Result<SuggestDecision, PolicyError> {
        self.invocations.fetch_add(1, Ordering::SeqCst);
        self.gate.wait();
        Ok(SuggestDecision::from_flat(
            req,
            vec![TrialSuggestion::default(); req.total_count()],
        ))
    }
}

fn config(name: &str) -> StudyConfig {
    let mut c = StudyConfig::new(name);
    c.search_space.add_float("x", 0.0, 1.0, ScaleType::Linear);
    c.add_metric(MetricInformation::maximize("score"));
    c.algorithm = Algorithm::Custom("SLOW".into());
    c.seed = 3;
    c
}

fn wait_for(what: &str, deadline: Duration, mut cond: impl FnMut() -> bool) -> bool {
    let by = Instant::now() + deadline;
    while !cond() {
        if Instant::now() >= by {
            note(&format!("WARN  timed out waiting for {what}"));
            return false;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    true
}

fn main() {
    let policy_workers = if soak() { 4 } else { 2 };
    // One study (no cross-study coalescing) per client: every operation
    // needs its own policy run, so P run and the rest queue.
    let clients = 3 * policy_workers + 2;

    section(&format!(
        "C-ASYNC-DISPATCH: {clients} clients vs {policy_workers} policy workers \
         (gated slow policy), {FE_WORKERS} front-end workers"
    ));

    let ds: Arc<dyn Datastore> = Arc::new(InMemoryDatastore::new());
    let gate = Arc::new(Gate::default());
    let invocations = Arc::new(AtomicUsize::new(0));
    let (g, inv) = (Arc::clone(&gate), Arc::clone(&invocations));
    let service = build_service(
        Arc::clone(&ds),
        move |reg| {
            reg.register(
                "SLOW",
                Arc::new(move |_| {
                    Box::new(SlowPolicy {
                        gate: Arc::clone(&g),
                        invocations: Arc::clone(&inv),
                    })
                }),
            );
        },
        policy_workers,
    );
    let server = VizierServer::start_with(
        Arc::clone(&service),
        "127.0.0.1:0",
        ServerOptions { workers: FE_WORKERS, ..Default::default() },
    )
    .unwrap();
    let addr = server.local_addr().to_string();

    let handles: Vec<_> = (0..clients)
        .map(|i| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let study = format!("async-{i}");
                let mut client = VizierClient::load_or_create_study(
                    Box::new(TcpTransport::connect(&addr).unwrap()),
                    &study,
                    &config(&study),
                    "bench",
                )
                .unwrap();
                client.get_suggestions(1).unwrap().len()
            })
        })
        .collect();

    // Every client accepted and parked: the server holds `clients`
    // in-flight operations on `policy_workers` policy threads.
    let fe = Arc::clone(server.frontend_metrics());
    let all_parked = wait_for("all clients to park in WaitOperation", Duration::from_secs(60), || {
        fe.parked_responses() == clients as u64
    });
    let in_flight = service.metrics.in_flight_policy_jobs();
    let pending = ds.pending_operations().unwrap().len();
    let fe_threads = threads_with_prefix("vizier-fe");
    note(&format!(
        "while gated: {in_flight} in-flight ops ({pending} pending in ds), \
         {} parked responses, {:?} vizier-fe threads, {} policy runs started",
        fe.parked_responses(),
        fe_threads,
        invocations.load(Ordering::SeqCst)
    ));

    check_strict(
        "clients-parked",
        all_parked,
        &format!("{} of {clients} waiters parked server-side", fe.parked_responses()),
    );
    check_strict(
        "in-flight-exceeds-3x-policy-workers",
        in_flight > (3 * policy_workers) as u64,
        &format!(
            "{in_flight} in-flight suggest ops on {policy_workers} policy workers \
             (> {} required)",
            3 * policy_workers
        ),
    );
    match fe_threads {
        Some(n) => check_strict(
            "fe-thread-budget",
            n <= FE_WORKERS + 2,
            &format!("{clients} parked waiters on {n} threads (budget {})", FE_WORKERS + 2),
        ),
        None => note("no /proc thread names on this platform: skipping thread-budget verdict"),
    }

    // Open the gate: every parked client must complete.
    let wait_ops_at_release = service.metrics.histogram("WaitOperation").count();
    let sw = Instant::now();
    gate.release();
    let mut served = 0usize;
    for h in handles {
        served += h.join().unwrap();
    }
    let wake_to_done = sw.elapsed();
    note(&format!(
        "gate release -> all {clients} clients done in {wake_to_done:?} \
         (wait_wakeup mean {:.1} us, p99 {} us)",
        service.metrics.wait_wakeup.mean_micros(),
        service.metrics.wait_wakeup.quantile_micros(0.99),
    ));

    check_strict(
        "all-clients-served",
        served == clients,
        &format!("{served} suggestions delivered to {clients} clients"),
    );
    // The acceptance bar: completion is pushed over the parked wait —
    // zero GetOperation busy-polling, and no client needed an extra
    // round-trip after the policies finished (its parked WaitOperation
    // carried the result).
    let get_ops = service.metrics.histogram("GetOperation").count();
    let wait_ops = service.metrics.histogram("WaitOperation").count();
    check_strict(
        "no-get-operation-busy-poll",
        get_ops == 0,
        &format!("{get_ops} GetOperation calls from the new client path"),
    );
    check_strict(
        "single-roundtrip-wakeup",
        wait_ops == wait_ops_at_release && wait_ops >= clients as u64,
        &format!(
            "{wait_ops} WaitOperation calls total, {wait_ops_at_release} already parked at \
             release: completions rode the parked waits"
        ),
    );
    check_strict(
        "in-flight-gauge-drains",
        service.metrics.in_flight_policy_jobs() == 0,
        &format!("{} in-flight after completion", service.metrics.in_flight_policy_jobs()),
    );

    server.shutdown();
    let leftover = threads_with_prefix("vizier-fe");
    if let Some(n) = leftover {
        check_strict(
            "shutdown-no-leak",
            n == 0,
            &format!("{n} vizier-fe threads after shutdown"),
        );
    }

    finish("async_dispatch");
}
