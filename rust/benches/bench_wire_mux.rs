//! C-WIRE-MUX: what connection multiplexing buys. One wire-v2
//! connection carrying N concurrent in-flight RPCs is compared against
//! the v1 shape of the same load — N separate connections, one blocking
//! RPC each — and the watch-stream path is checked structurally: the
//! number of `wait_wakeup` events must never exceed the number of
//! operation state transitions (the stream pushes per transition; it
//! never busy-wakes).
//!
//! Results land in `BENCH_WIRE_MUX.json` at the repo root (see
//! `bench_baselines/README.md` for the comparison gate).

use ossvizier::client::transport::{call, TcpTransport, Transport};
use ossvizier::client::VizierClient;
use ossvizier::pyvizier::{Algorithm, Measurement, MetricInformation, ScaleType, StudyConfig};
use ossvizier::service::{in_memory_service, VizierServer};
use ossvizier::util::benchkit::{bench, check_strict, finish, note, section};
use ossvizier::wire::framing::Method;
use ossvizier::wire::messages::EmptyResponse;
use std::time::Duration;

/// Concurrent in-flight RPCs per round (the acceptance floor is 8).
const INFLIGHT: usize = 8;

fn soak() -> bool {
    std::env::var_os("OSSVIZIER_SOAK").is_some()
}

fn ping(t: &mut TcpTransport) {
    let _: EmptyResponse =
        call(t as &mut dyn Transport, Method::Ping, &EmptyResponse::default()).unwrap();
}

/// One round: `INFLIGHT` threads issue `per_thread` pings each,
/// concurrently, over whatever transports the caller built. Wall time of
/// the whole round is what [`bench`] samples.
fn round(transports: &mut [TcpTransport], per_thread: usize) {
    std::thread::scope(|scope| {
        for t in transports.iter_mut() {
            scope.spawn(move || {
                for _ in 0..per_thread {
                    ping(t);
                }
            });
        }
    });
}

fn main() {
    let per_thread = if soak() { 100 } else { 25 };
    section(&format!(
        "C-WIRE-MUX: {INFLIGHT} concurrent in-flight RPC lanes x {per_thread} pings/round, \
         one multiplexed v2 connection vs {INFLIGHT} v1 connections"
    ));

    let server = VizierServer::start(in_memory_service(2), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();

    // --- v2: all lanes share ONE socket, demuxed by correlation id.
    let base = TcpTransport::connect(&addr).unwrap();
    check_strict(
        "hello-negotiates-v2",
        base.wire_version() == 2,
        &format!("negotiated wire version {}", base.wire_version()),
    );
    let mut shares: Vec<TcpTransport> =
        (0..INFLIGHT).map(|_| base.try_share().expect("v2 share")).collect();
    let mux = bench(&format!("wire_mux/round_{INFLIGHT}lanes_one_mux_conn"), || {
        round(&mut shares, per_thread);
    });
    let fe = server.frontend_metrics();
    check_strict(
        "mux-lanes-share-one-socket",
        fe.active_connections() == 1,
        &format!("{} active connections under the mux round", fe.active_connections()),
    );

    // --- v1 baseline: the same load needs one connection per lane.
    let mut v1_conns: Vec<TcpTransport> = (0..INFLIGHT)
        .map(|_| {
            let mut t = TcpTransport::connect(&addr).unwrap();
            t.force_v1();
            t
        })
        .collect();
    let v1 = bench(&format!("wire_mux/round_{INFLIGHT}lanes_v1_conns"), || {
        round(&mut v1_conns, per_thread);
    });

    let rpcs = (INFLIGHT * per_thread) as f64;
    note(&format!(
        "one mux conn {:>9.0} req/s   {INFLIGHT} v1 conns {:>9.0} req/s",
        rpcs / (mux.mean_us() / 1e6),
        rpcs / (v1.mean_us() / 1e6),
    ));
    server.shutdown();

    // ------------------------------------------------------------------
    // Watch-stream wakeup accounting: run a real tuning loop over v2 and
    // compare `wait_wakeup` events against operation state transitions.
    // Every suggest operation transitions exactly once (pending -> done),
    // so wakeups <= completed operations — a deterministic counter fact,
    // not a timing.
    // ------------------------------------------------------------------
    let ops = if soak() { 200 } else { 50 };
    section(&format!("C-WIRE-MUX: watch-stream wakeups over {ops} suggest operations"));
    let service = in_memory_service(2);
    let server = VizierServer::start(service.clone(), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();

    let mut config = StudyConfig::new("mux-watch");
    config.search_space.add_float("x", 0.0, 1.0, ScaleType::Linear);
    config.add_metric(MetricInformation::maximize("score"));
    config.algorithm = Algorithm::RandomSearch;
    let transport = Box::new(TcpTransport::connect(&addr).unwrap());
    let mut client =
        VizierClient::load_or_create_study(transport, "mux-watch", &config, "w0").unwrap();
    for _ in 0..ops {
        let t = &client.get_suggestions(1).unwrap()[0];
        client
            .complete_trial(t.id, Some(&Measurement::new(1).with_metric("score", 0.5)))
            .unwrap();
    }
    let wakeups = service.metrics.wait_wakeup.count();
    let transitions = ops as u64; // one pending->done transition per op
    note(&format!("{wakeups} wait wakeups over {transitions} operation transitions"));
    check_strict(
        "watch-wakeups-bounded-by-transitions",
        wakeups <= transitions,
        &format!("{wakeups} wakeups <= {transitions} transitions"),
    );
    check_strict(
        "zero-getoperation-polling",
        service.metrics.histogram("GetOperation").count() == 0,
        &format!("{} GetOperation calls", service.metrics.histogram("GetOperation").count()),
    );
    check_strict(
        "watch-streams-drain",
        service.metrics.watch_streams() == 0,
        &format!("{} live watch streams after the loop", service.metrics.watch_streams()),
    );
    server.shutdown();

    finish("WIRE_MUX");
}
