//! C-FRONTEND: front-end concurrency model — the bounded worker pool
//! (event loop over `poll(2)` + N workers) vs the legacy
//! thread-per-connection baseline, under the canonical Vizier fleet
//! shape: 1000+ mostly-idle worker connections with a small hot subset
//! actually suggesting/completing trials.
//!
//! Structural assertions (always enforced): the pool serves the whole
//! fleet with at most `workers + 2` service threads (the baseline needs
//! one thread per connection), the `active_connections` gauge tracks the
//! fleet, and shutdown leaves zero front-end threads in both modes (the
//! baseline historically leaked its `vizier-conn` threads).
//!
//! Timing assertions (lax-gated, enforced in the nightly soak job): hot
//! subset throughput under the pool must not lose to the baseline.
//!
//! `OSSVIZIER_SOAK=1` scales the fleet and request counts up.
//! Results land in `BENCH_FRONTEND.json` at the repo root.

use ossvizier::client::{TcpTransport, VizierClient};
use ossvizier::pyvizier::{Algorithm, Measurement, MetricInformation, StudyConfig};
use ossvizier::service::{in_memory_service, ServerOptions, VizierServer};
use ossvizier::testing::procfs::{soft_fd_limit, threads_with_prefix};
use ossvizier::util::benchkit::{check, check_strict, finish, note, section};
use ossvizier::util::time::Stopwatch;
use ossvizier::wire::framing::{read_response, write_request, Method};
use ossvizier::wire::messages::{EmptyResponse, ScaleType};
use std::io::BufReader;
use std::net::TcpStream;

const WORKERS: usize = 8;
const PING_THREADS: usize = 4;
const HOT_DRIVERS: usize = 8;

fn soak() -> bool {
    std::env::var_os("OSSVIZIER_SOAK").is_some()
}

/// Size the idle fleet to the soft fd limit so the bench never hits
/// EMFILE. Worst case is legacy mode, where one connection costs four
/// fds in this single-process bench: the client socket, the accepted
/// socket, the shutdown-registry `try_clone`, and the `serve_connection`
/// reader clone.
fn max_idle_connections(target: usize) -> usize {
    const FDS_PER_CONN: u64 = 4;
    let Some(soft) = soft_fd_limit() else { return target };
    let budget = (soft.saturating_sub(256) / FDS_PER_CONN) as usize;
    if budget < target {
        note(&format!("fd soft limit {soft}: clamping idle fleet {target} -> {budget}"));
        return budget;
    }
    target
}

fn config(name: &str) -> StudyConfig {
    let mut c = StudyConfig::new(name);
    c.search_space.add_float("x", 0.0, 1.0, ScaleType::Linear);
    c.add_metric(MetricInformation::maximize("score"));
    c.algorithm = Algorithm::RandomSearch;
    c.seed = 7;
    c
}

fn ping(stream: &mut TcpStream) {
    write_request(stream, Method::Ping, &EmptyResponse::default()).unwrap();
    let mut r = BufReader::new(stream.try_clone().unwrap());
    let _: EmptyResponse = read_response(&mut r).unwrap();
}

struct ModeResult {
    label: &'static str,
    service_threads: Option<usize>,
    ping_rps: f64,
    workload_rps: f64,
    leftover_threads: Option<usize>,
    gauge_ok: bool,
}

fn run_mode(
    legacy: bool,
    idle: usize,
    ping_reqs: usize,
    rounds: usize,
) -> ModeResult {
    let label = if legacy { "legacy thread-per-connection" } else { "worker pool" };
    let prefix = if legacy { "vizier-conn" } else { "vizier-fe" };
    let service = in_memory_service(16);
    let server = VizierServer::start_with(
        service,
        "127.0.0.1:0",
        ServerOptions { workers: WORKERS, legacy_threads: legacy, ..Default::default() },
    )
    .unwrap();
    let addr = server.local_addr().to_string();

    // The idle fleet: connect, prove liveness with one ping, then sit.
    let mut fleet = Vec::with_capacity(idle);
    for _ in 0..idle {
        let mut s = TcpStream::connect(&addr).unwrap();
        ping(&mut s);
        fleet.push(s);
    }
    let service_threads = threads_with_prefix(prefix);
    let gauge = server.frontend_metrics().active_connections();
    let gauge_ok = gauge == idle as u64;
    note(&format!(
        "{label}: {idle} idle connections -> {} front-end threads, gauge {}",
        service_threads.map_or("?".into(), |n| n.to_string()),
        gauge
    ));

    // Hot subset A: raw ping round-trips (pure front-end overhead).
    let sw = Stopwatch::start();
    let handles: Vec<_> = (0..PING_THREADS)
        .map(|_| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut s = TcpStream::connect(&addr).unwrap();
                for _ in 0..ping_reqs {
                    ping(&mut s);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let ping_rps = (PING_THREADS * ping_reqs) as f64 / sw.elapsed().as_secs_f64();

    // Hot subset B: the real workload — suggest + complete cycles, one
    // study per driver.
    let sw = Stopwatch::start();
    let handles: Vec<_> = (0..HOT_DRIVERS)
        .map(|d| {
            let addr = addr.clone();
            let study = format!("fe-{}-{d}", if legacy { "legacy" } else { "pool" });
            std::thread::spawn(move || {
                let mut client = VizierClient::load_or_create_study(
                    Box::new(TcpTransport::connect(&addr).unwrap()),
                    &study,
                    &config(&study),
                    "hot",
                )
                .unwrap();
                for i in 0..rounds {
                    let t = client.get_suggestions(1).unwrap().remove(0);
                    client
                        .complete_trial(
                            t.id,
                            Some(&Measurement::new(1).with_metric("score", i as f64)),
                        )
                        .unwrap();
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let workload_rps = (HOT_DRIVERS * rounds) as f64 / sw.elapsed().as_secs_f64();

    drop(fleet);
    server.shutdown();
    let leftover_threads = threads_with_prefix(prefix);

    ModeResult { label, service_threads, ping_rps, workload_rps, leftover_threads, gauge_ok }
}

fn main() {
    let idle = max_idle_connections(if soak() { 2500 } else { 1000 });
    let ping_reqs = if soak() { 10_000 } else { 2_000 };
    let rounds = if soak() { 40 } else { 12 };

    section(&format!(
        "C-FRONTEND: {idle} idle connections + hot subset \
         ({PING_THREADS} pingers x {ping_reqs}, {HOT_DRIVERS} drivers x {rounds} trials), \
         pool workers = {WORKERS}"
    ));

    let pool = run_mode(false, idle, ping_reqs, rounds);
    let legacy = run_mode(true, idle, ping_reqs, rounds);

    for r in [&pool, &legacy] {
        note(&format!(
            "{:<30} ping {:>9.0} req/s   suggest+complete {:>7.1} trials/s",
            r.label, r.ping_rps, r.workload_rps
        ));
    }

    // Structural verdicts — enforced regardless of OSSVIZIER_BENCH_LAX.
    match (pool.service_threads, legacy.service_threads) {
        (Some(pool_threads), Some(legacy_threads)) => {
            check_strict(
                "pool-thread-budget",
                pool_threads <= WORKERS + 2,
                &format!(
                    "{idle} connections on {pool_threads} threads (budget {}; \
                     legacy model used {legacy_threads})",
                    WORKERS + 2
                ),
            );
            check_strict(
                "pool-shutdown-no-leak",
                pool.leftover_threads == Some(0),
                &format!("{:?} vizier-fe threads after shutdown", pool.leftover_threads),
            );
            check_strict(
                "legacy-shutdown-no-leak",
                legacy.leftover_threads == Some(0),
                &format!("{:?} vizier-conn threads after shutdown", legacy.leftover_threads),
            );
        }
        _ => note("no /proc thread names on this platform: skipping thread-budget verdicts"),
    }
    check_strict(
        "active-connections-gauge",
        pool.gauge_ok && legacy.gauge_ok,
        &format!("gauge == fleet size (pool {}, legacy {})", pool.gauge_ok, legacy.gauge_ok),
    );

    // Timing verdict — lax-gated on PR runners, enforced in the soak
    // job. 0.85x is the repo-standard ~15% runner-noise slack (the same
    // slack bench_datastore applies to its "must not lose" comparisons).
    check(
        "hot-throughput-vs-legacy",
        pool.workload_rps >= legacy.workload_rps * 0.85,
        &format!(
            "pool {:.1} trials/s vs legacy {:.1} trials/s \
             (>= baseline within the standard 15% noise slack)",
            pool.workload_rps, legacy.workload_rps
        ),
    );

    finish("FRONTEND");
}
