//! C-FRONTEND: front-end concurrency model — the bounded worker pool
//! (event loop over `poll(2)` + N workers) vs the legacy
//! thread-per-connection baseline, under the canonical Vizier fleet
//! shape: 1000+ mostly-idle worker connections with a small hot subset
//! actually suggesting/completing trials.
//!
//! Structural assertions (always enforced): the pool serves the whole
//! fleet with at most `workers + 2` service threads (the baseline needs
//! one thread per connection), the `active_connections` gauge tracks the
//! fleet, and shutdown leaves zero front-end threads in both modes (the
//! baseline historically leaked its `vizier-conn` threads).
//!
//! Timing assertions (lax-gated, enforced in the nightly soak job): hot
//! subset throughput under the pool must not lose to the baseline.
//!
//! C-FRONTEND-EPOLL: the same pool front-end under its two readiness
//! backends — `--poller=poll` (the interest set is rebuilt and scanned
//! on every wakeup, O(total connections)) vs `--poller=epoll`
//! (incremental registration, O(ready)). A large parked fleet with a
//! small hot subset makes the difference visible: the strict verdicts
//! pin the per-wakeup scan cost (poll's must scale with the fleet,
//! epoll's must not), and a lax-gated check keeps epoll's hot-path
//! throughput at least at the poll baseline.
//!
//! `OSSVIZIER_SOAK=1` scales the fleet and request counts up.
//! Results land in `BENCH_FRONTEND.json` at the repo root.

use ossvizier::client::{TcpTransport, VizierClient};
use ossvizier::pyvizier::{Algorithm, Measurement, MetricInformation, StudyConfig};
use ossvizier::service::{in_memory_service, ServerOptions, VizierServer};
use ossvizier::testing::procfs::{soft_fd_limit, threads_with_prefix};
use ossvizier::util::benchkit::{bench_with_budget, check, check_strict, finish, note, section};
use ossvizier::util::netpoll::PollerKind;
use ossvizier::util::time::Stopwatch;
use ossvizier::wire::framing::{read_response, write_request, Method};
use ossvizier::wire::messages::{EmptyResponse, ScaleType};
use std::io::BufReader;
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

const WORKERS: usize = 8;
const PING_THREADS: usize = 4;
const HOT_DRIVERS: usize = 8;

fn soak() -> bool {
    std::env::var_os("OSSVIZIER_SOAK").is_some()
}

/// Size the idle fleet to the soft fd limit so the bench never hits
/// EMFILE. Worst case is legacy mode, where one connection costs four
/// fds in this single-process bench: the client socket, the accepted
/// socket, the shutdown-registry `try_clone`, and the `serve_connection`
/// reader clone.
fn max_idle_connections(target: usize) -> usize {
    const FDS_PER_CONN: u64 = 4;
    let Some(soft) = soft_fd_limit() else { return target };
    let budget = (soft.saturating_sub(256) / FDS_PER_CONN) as usize;
    if budget < target {
        note(&format!("fd soft limit {soft}: clamping idle fleet {target} -> {budget}"));
        return budget;
    }
    target
}

fn config(name: &str) -> StudyConfig {
    let mut c = StudyConfig::new(name);
    c.search_space.add_float("x", 0.0, 1.0, ScaleType::Linear);
    c.add_metric(MetricInformation::maximize("score"));
    c.algorithm = Algorithm::RandomSearch;
    c.seed = 7;
    c
}

fn ping(stream: &mut TcpStream) {
    write_request(stream, Method::Ping, &EmptyResponse::default()).unwrap();
    let mut r = BufReader::new(stream.try_clone().unwrap());
    let _: EmptyResponse = read_response(&mut r).unwrap();
}

struct ModeResult {
    label: &'static str,
    service_threads: Option<usize>,
    ping_rps: f64,
    workload_rps: f64,
    leftover_threads: Option<usize>,
    gauge_ok: bool,
}

fn run_mode(
    legacy: bool,
    idle: usize,
    ping_reqs: usize,
    rounds: usize,
) -> ModeResult {
    let label = if legacy { "legacy thread-per-connection" } else { "worker pool" };
    let prefix = if legacy { "vizier-conn" } else { "vizier-fe" };
    let service = in_memory_service(16);
    let server = VizierServer::start_with(
        service,
        "127.0.0.1:0",
        ServerOptions { workers: WORKERS, legacy_threads: legacy, ..Default::default() },
    )
    .unwrap();
    let addr = server.local_addr().to_string();

    // The idle fleet: connect, prove liveness with one ping, then sit.
    let mut fleet = Vec::with_capacity(idle);
    for _ in 0..idle {
        let mut s = TcpStream::connect(&addr).unwrap();
        ping(&mut s);
        fleet.push(s);
    }
    let service_threads = threads_with_prefix(prefix);
    let gauge = server.frontend_metrics().active_connections();
    let gauge_ok = gauge == idle as u64;
    note(&format!(
        "{label}: {idle} idle connections -> {} front-end threads, gauge {}",
        service_threads.map_or("?".into(), |n| n.to_string()),
        gauge
    ));

    // Hot subset A: raw ping round-trips (pure front-end overhead).
    let sw = Stopwatch::start();
    let handles: Vec<_> = (0..PING_THREADS)
        .map(|_| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut s = TcpStream::connect(&addr).unwrap();
                for _ in 0..ping_reqs {
                    ping(&mut s);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let ping_rps = (PING_THREADS * ping_reqs) as f64 / sw.elapsed().as_secs_f64();

    // Hot subset B: the real workload — suggest + complete cycles, one
    // study per driver.
    let sw = Stopwatch::start();
    let handles: Vec<_> = (0..HOT_DRIVERS)
        .map(|d| {
            let addr = addr.clone();
            let study = format!("fe-{}-{d}", if legacy { "legacy" } else { "pool" });
            std::thread::spawn(move || {
                let mut client = VizierClient::load_or_create_study(
                    Box::new(TcpTransport::connect(&addr).unwrap()),
                    &study,
                    &config(&study),
                    "hot",
                )
                .unwrap();
                for i in 0..rounds {
                    let t = client.get_suggestions(1).unwrap().remove(0);
                    client
                        .complete_trial(
                            t.id,
                            Some(&Measurement::new(1).with_metric("score", i as f64)),
                        )
                        .unwrap();
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let workload_rps = (HOT_DRIVERS * rounds) as f64 / sw.elapsed().as_secs_f64();

    drop(fleet);
    server.shutdown();
    let leftover_threads = threads_with_prefix(prefix);

    ModeResult { label, service_threads, ping_rps, workload_rps, leftover_threads, gauge_ok }
}

/// Size the C-FRONTEND-EPOLL parked fleet to the soft fd limit. Pool
/// mode costs two fds per connection in this single-process bench (the
/// client socket and the accepted socket); the 256-fd slack covers the
/// hot subset, the wake pipe, and the epoll fd.
fn max_parked_connections(target: usize) -> usize {
    const FDS_PER_CONN: u64 = 2;
    let Some(soft) = soft_fd_limit() else { return target };
    let budget = (soft.saturating_sub(256) / FDS_PER_CONN) as usize;
    if budget < target {
        note(&format!("fd soft limit {soft}: clamping parked fleet {target} -> {budget}"));
        return budget;
    }
    target
}

struct PollerResult {
    kind: PollerKind,
    ping_rps: f64,
    wakeups: u64,
    scan_cost: u64,
}

impl PollerResult {
    /// Event-loop scan cost per wakeup during the hot phase: pollfds
    /// scanned (poll backend) or events delivered (epoll backend).
    fn scan_per_wakeup(&self) -> f64 {
        self.scan_cost as f64 / self.wakeups.max(1) as f64
    }
}

fn run_poller_mode(kind: PollerKind, parked: usize, ping_reqs: usize) -> PollerResult {
    let service = in_memory_service(16);
    let server = VizierServer::start_with(
        service,
        "127.0.0.1:0",
        ServerOptions { workers: WORKERS, poller: kind, ..Default::default() },
    )
    .unwrap();
    let addr = server.local_addr().to_string();
    let metrics = Arc::clone(server.frontend_metrics());

    // Park the fleet: connect, prove liveness with one ping (which also
    // exercises the register -> worker hand-off -> re-register churn on
    // every connection), then sit idle for the rest of the run.
    let mut fleet = Vec::with_capacity(parked);
    for _ in 0..parked {
        let mut s = TcpStream::connect(&addr).unwrap();
        ping(&mut s);
        fleet.push(s);
    }

    // Only the hot phase counts toward the per-wakeup scan cost, so
    // snapshot the loop counters after the fleet has settled.
    let wakeups0 = metrics.loop_wakeups();
    let scan0 = metrics.loop_scan_cost();

    let sw = Stopwatch::start();
    let handles: Vec<_> = (0..PING_THREADS)
        .map(|_| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut s = TcpStream::connect(&addr).unwrap();
                for _ in 0..ping_reqs {
                    ping(&mut s);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let ping_rps = (PING_THREADS * ping_reqs) as f64 / sw.elapsed().as_secs_f64();

    // Single-connection round-trip with the whole fleet parked: the
    // per-request trajectory the baseline JSON tracks across runs. The
    // name deliberately omits the fleet size, which is fd-limit-clamped
    // and would otherwise make baselines incomparable across runners.
    let mut hot = TcpStream::connect(&addr).unwrap();
    bench_with_budget(
        &format!("frontend/ping_rtt_{}_parked", kind.name()),
        Duration::from_millis(300),
        || ping(&mut hot),
    );

    let wakeups = metrics.loop_wakeups() - wakeups0;
    let scan_cost = metrics.loop_scan_cost() - scan0;
    drop(hot);
    drop(fleet);
    server.shutdown();
    PollerResult { kind, ping_rps, wakeups, scan_cost }
}

fn main() {
    let idle = max_idle_connections(if soak() { 2500 } else { 1000 });
    let ping_reqs = if soak() { 10_000 } else { 2_000 };
    let rounds = if soak() { 40 } else { 12 };

    section(&format!(
        "C-FRONTEND: {idle} idle connections + hot subset \
         ({PING_THREADS} pingers x {ping_reqs}, {HOT_DRIVERS} drivers x {rounds} trials), \
         pool workers = {WORKERS}"
    ));

    let pool = run_mode(false, idle, ping_reqs, rounds);
    let legacy = run_mode(true, idle, ping_reqs, rounds);

    for r in [&pool, &legacy] {
        note(&format!(
            "{:<30} ping {:>9.0} req/s   suggest+complete {:>7.1} trials/s",
            r.label, r.ping_rps, r.workload_rps
        ));
    }

    // Structural verdicts — enforced regardless of OSSVIZIER_BENCH_LAX.
    match (pool.service_threads, legacy.service_threads) {
        (Some(pool_threads), Some(legacy_threads)) => {
            check_strict(
                "pool-thread-budget",
                pool_threads <= WORKERS + 2,
                &format!(
                    "{idle} connections on {pool_threads} threads (budget {}; \
                     legacy model used {legacy_threads})",
                    WORKERS + 2
                ),
            );
            check_strict(
                "pool-shutdown-no-leak",
                pool.leftover_threads == Some(0),
                &format!("{:?} vizier-fe threads after shutdown", pool.leftover_threads),
            );
            check_strict(
                "legacy-shutdown-no-leak",
                legacy.leftover_threads == Some(0),
                &format!("{:?} vizier-conn threads after shutdown", legacy.leftover_threads),
            );
        }
        _ => note("no /proc thread names on this platform: skipping thread-budget verdicts"),
    }
    check_strict(
        "active-connections-gauge",
        pool.gauge_ok && legacy.gauge_ok,
        &format!("gauge == fleet size (pool {}, legacy {})", pool.gauge_ok, legacy.gauge_ok),
    );

    // Timing verdict — lax-gated on PR runners, enforced in the soak
    // job. 0.85x is the repo-standard ~15% runner-noise slack (the same
    // slack bench_datastore applies to its "must not lose" comparisons).
    check(
        "hot-throughput-vs-legacy",
        pool.workload_rps >= legacy.workload_rps * 0.85,
        &format!(
            "pool {:.1} trials/s vs legacy {:.1} trials/s \
             (>= baseline within the standard 15% noise slack)",
            pool.workload_rps, legacy.workload_rps
        ),
    );

    // ------------------------------------------------------------------
    // C-FRONTEND-EPOLL: poll(2) baseline vs epoll on the same pool
    // front-end, with a much larger parked fleet so the per-wakeup scan
    // cost difference is unambiguous.
    // ------------------------------------------------------------------
    let parked = max_parked_connections(if soak() { 8_000 } else { 5_000 });
    section(&format!(
        "C-FRONTEND-EPOLL: {parked} parked connections, hot subset \
         ({PING_THREADS} pingers x {ping_reqs}), poll(2) vs epoll"
    ));

    let poll_r = run_poller_mode(PollerKind::Poll, parked, ping_reqs);
    let epoll_r = run_poller_mode(PollerKind::Epoll, parked, ping_reqs);

    for r in [&poll_r, &epoll_r] {
        note(&format!(
            "{:<6} ping {:>9.0} req/s   {} wakeups, scan cost {} ({:.1}/wakeup)",
            r.kind.name(),
            r.ping_rps,
            r.wakeups,
            r.scan_cost,
            r.scan_per_wakeup()
        ));
    }

    // Structural verdicts: the poll baseline must pay O(fleet) on every
    // wakeup (otherwise the comparison proves nothing), and epoll must
    // pay O(ready) — a small constant that does not scale with the
    // parked fleet. Both are deterministic counter facts, not timings.
    check_strict(
        "poll-wakeup-cost-scales-with-fleet",
        poll_r.wakeups > 0 && poll_r.scan_per_wakeup() >= parked as f64,
        &format!(
            "poll(2) scans {:.1} pollfds/wakeup with {parked} parked (O(fleet) baseline)",
            poll_r.scan_per_wakeup()
        ),
    );
    check_strict(
        "epoll-wakeup-cost-o-ready",
        epoll_r.wakeups > 0
            && epoll_r.scan_per_wakeup() <= 64.0
            && epoll_r.scan_per_wakeup() * 8.0 <= parked as f64,
        &format!(
            "epoll delivers {:.1} events/wakeup with {parked} parked (O(ready), not O(fleet))",
            epoll_r.scan_per_wakeup()
        ),
    );

    // Timing verdict — lax-gated on PR runners, enforced in soak.
    check(
        "epoll-hot-throughput-vs-poll",
        epoll_r.ping_rps >= poll_r.ping_rps * 0.85,
        &format!(
            "epoll {:.0} req/s vs poll {:.0} req/s \
             (>= baseline within the standard 15% noise slack)",
            epoll_r.ping_rps, poll_r.ping_rps
        ),
    );

    finish("FRONTEND");
}
