//! C-DS: datastore performance — in-memory vs WAL-durable CRUD, WAL
//! recovery time (the cost of server-side fault tolerance), the effect of
//! log compaction, and multi-threaded contention (sharding vs a single
//! lock; WAL group commit vs serial fsync).
//!
//! C-DS-SNAP: copy-on-write snapshot reads vs the lock-per-read
//! baseline — a 95/5 read/write mix on one contended shard with the
//! background compactor running, plus strict zero-lock and mode-gating
//! verdicts over the `datastore.*` snapshot/contention metrics.

use ossvizier::datastore::memory::InMemoryDatastore;
use ossvizier::datastore::query::TrialFilter;
use ossvizier::datastore::wal::{WalDatastore, WalOptions};
use ossvizier::datastore::Datastore;
use ossvizier::util::benchkit::{bench, check, check_strict, finish, note, section};
use ossvizier::util::time::Stopwatch;
use ossvizier::wire::messages::{StudyProto, TrialProto};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

fn tmp(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("ossvizier-bench-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d.join("store.wal")
}

fn study(name: &str) -> StudyProto {
    StudyProto { display_name: name.into(), ..Default::default() }
}

fn percentile(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = ((sorted.len() as f64 * q).ceil() as usize).clamp(1, sorted.len()) - 1;
    sorted[idx]
}

struct SnapProbe {
    read_rps: f64,
    wall_ms: f64,
    p99_us: u64,
    commits_during: u64,
    locked_reads: u64,
    snapshot_loads: u64,
    snapshot_publishes: u64,
    compactions: u64,
}

/// C-DS-SNAP worker mix: 8 threads share ONE study (one shard — the
/// worst case for reader/writer interference), each thread running a
/// 95/5 read/write loop while a forced compaction cycle runs in the
/// background. Reads are bounded `query_trials` window scans; writes
/// are durable `create_trial` commits whose latency is recorded while
/// the compaction is in flight (the C-WAL-ROTATE stall-probe pattern).
fn snap_mix(cow: bool, tag: &str) -> SnapProbe {
    const THREADS: usize = 8;
    const OPS_PER_THREAD: usize = 3_000;
    const WRITE_EVERY: usize = 20; // 1 write per 20 ops = the 95/5 mix
    const PRELOAD: u64 = 8_000;
    const READ_WINDOW: u64 = 512;
    let opts = WalOptions {
        segment_bytes: Some(1 << 20),
        datastore_cow: Some(cow),
        ..WalOptions::default()
    };
    let ds = Arc::new(WalDatastore::open_with_options(tmp(tag), opts).unwrap());
    let s = ds.create_study(study("snap")).unwrap();
    for _ in 0..PRELOAD {
        ds.create_trial(&s.name, TrialProto::default()).unwrap();
    }
    let compacting = Arc::new(AtomicBool::new(false));
    let sw = Stopwatch::start();
    let handles: Vec<_> = (0..THREADS)
        .map(|worker| {
            let ds = Arc::clone(&ds);
            let name = s.name.clone();
            let compacting = Arc::clone(&compacting);
            std::thread::spawn(move || {
                let mut during: Vec<u64> = Vec::new();
                let mut reads = 0u64;
                for i in 0..OPS_PER_THREAD {
                    if i % WRITE_EVERY == WRITE_EVERY - 1 {
                        let tagged = compacting.load(Ordering::Relaxed);
                        let csw = Stopwatch::start();
                        ds.create_trial(&name, TrialProto::default()).unwrap();
                        // A commit that *started* during the compaction
                        // window counts even if the window closed while
                        // it was blocked — that is exactly the
                        // perturbation being measured.
                        if tagged || compacting.load(Ordering::Relaxed) {
                            during.push(csw.elapsed_micros());
                        }
                    } else {
                        // Rotating bounded window over the preloaded id
                        // range: constant per-read work, all on the one
                        // contended shard.
                        let lo = ((worker * OPS_PER_THREAD + i) as u64 * 97) % PRELOAD + 1;
                        let filter = TrialFilter {
                            min_id: Some(lo),
                            max_id: Some(lo + READ_WINDOW),
                            ..Default::default()
                        };
                        std::hint::black_box(ds.query_trials(&name, &filter).unwrap());
                        reads += 1;
                    }
                }
                (reads, during)
            })
        })
        .collect();
    // Force a full compaction cycle mid-mix (background compactor; the
    // commit path keeps flowing either way — the probe measures by how
    // much it is perturbed).
    std::thread::sleep(std::time::Duration::from_millis(20));
    compacting.store(true, Ordering::Relaxed);
    ds.compact().unwrap();
    compacting.store(false, Ordering::Relaxed);
    let mut reads_total = 0u64;
    let mut during: Vec<u64> = Vec::new();
    for h in handles {
        let (r, d) = h.join().unwrap();
        reads_total += r;
        during.extend(d);
    }
    let wall_ms = sw.elapsed_millis_f64();
    during.sort_unstable();
    let dm = ds.datastore_metrics();
    SnapProbe {
        read_rps: reads_total as f64 / (wall_ms / 1e3),
        wall_ms,
        p99_us: percentile(&during, 0.99),
        commits_during: during.len() as u64,
        locked_reads: dm.locked_reads(),
        snapshot_loads: dm.snapshot_loads(),
        snapshot_publishes: dm.snapshot_publishes(),
        compactions: ds.metrics().compactions(),
    }
}

fn bench_snap() {
    section("C-DS-SNAP: 95/5 read/write mix on one shard, compactor running");
    let cow = snap_mix(true, "snap-cow");
    let off = snap_mix(false, "snap-off");
    note(&format!(
        "cow snapshots (default):  {:>9.0} reads/s ({:.2} ms wall), commit p99 during \
         compaction {} us ({} commits in window), {} publishes / {} snapshot loads / \
         {} locked reads, {} compaction(s)",
        cow.read_rps, cow.wall_ms, cow.p99_us, cow.commits_during,
        cow.snapshot_publishes, cow.snapshot_loads, cow.locked_reads, cow.compactions
    ));
    note(&format!(
        "lock-per-read baseline:   {:>9.0} reads/s ({:.2} ms wall), commit p99 during \
         compaction {} us ({} commits in window), {} locked reads  speedup {:.2}x",
        off.read_rps, off.wall_ms, off.p99_us, off.commits_during, off.locked_reads,
        cow.read_rps / off.read_rps
    ));
    // The headline acceptance verdicts. Both are structural enough to be
    // strict: the zero-lock one is a pure counter assertion, and the
    // throughput one is an in-process A/B on an identical workload where
    // the lock-free read path must not lose its own core scenario.
    check_strict(
        "ds-snap-zero-lock-compaction",
        cow.locked_reads == 0 && cow.snapshot_publishes > 0 && cow.compactions >= 1,
        &format!(
            "cow mode must complete the mix + a full compaction cycle with zero shard \
             read-lock acquisitions ({} locked reads, {} publishes, {} compactions)",
            cow.locked_reads, cow.snapshot_publishes, cow.compactions
        ),
    );
    check_strict(
        "ds-snap-mode-gating",
        off.locked_reads > 0 && off.snapshot_loads == 0 && off.snapshot_publishes == 0,
        &format!(
            "--datastore-cow=off must keep the recorded lock-per-read baseline \
             ({} locked reads, {} snapshot loads, {} publishes)",
            off.locked_reads, off.snapshot_loads, off.snapshot_publishes
        ),
    );
    check_strict(
        "ds-snap-cow-read-throughput",
        cow.read_rps > off.read_rps,
        &format!(
            "snapshot readers must outscale the lock baseline under a concurrent \
             writer ({:.0} vs {:.0} reads/s)",
            cow.read_rps, off.read_rps
        ),
    );
    // The C-WAL-ROTATE bound, restated for this bench: a background
    // compaction must not perturb commit latency — and the cow snapshot
    // takes no shard locks at all, so its p99 must stay within noise of
    // the paged baseline (15% + a 5 ms floor for shared runners).
    let bound_us = ((off.p99_us as f64) * 1.15).max(off.p99_us as f64 + 5_000.0);
    check(
        "ds-snap-commit-p99-no-regress",
        (cow.p99_us as f64) <= bound_us,
        &format!(
            "commit p99 during compaction: cow {} us vs baseline {} us (bound {bound_us:.0} us)",
            cow.p99_us, off.p99_us
        ),
    );

    // Steady-state single-thread read cost, for the ns/op baseline table.
    for (mode, label) in [
        (true, "cow:    query_trials 512-id window (10k-trial study)"),
        (false, "locked: query_trials 512-id window (10k-trial study)"),
    ] {
        let mem = InMemoryDatastore::with_shards_cow(16, mode);
        let s = mem.create_study(study("win")).unwrap();
        for _ in 0..10_000 {
            mem.create_trial(&s.name, TrialProto::default()).unwrap();
        }
        let mut lo = 1u64;
        bench(label, || {
            let filter = TrialFilter {
                min_id: Some(lo),
                max_id: Some(lo + 512),
                ..Default::default()
            };
            std::hint::black_box(mem.query_trials(&s.name, &filter).unwrap());
            lo = lo % 9_000 + 97;
        });
    }
}

fn main() {
    // Arm the lock-order detector for the whole binary when the caller
    // has not chosen: the C-DS-SNAP zero-lock verdicts must hold with
    // lockdep active, and every comparison below is in-process A/B, so
    // the uniform instrumentation cost cancels out (baselines are
    // refreshed from runs of this same binary).
    if std::env::var_os("OSSVIZIER_LOCKDEP").is_none() {
        std::env::set_var("OSSVIZIER_LOCKDEP", "1");
    }
    section("C-DS: trial create+complete cycle");
    {
        let mem = InMemoryDatastore::new();
        let s = mem.create_study(study("m")).unwrap();
        bench("in-memory: create_trial + mutate", || {
            let t = mem.create_trial(&s.name, TrialProto::default()).unwrap();
            mem.mutate_trial(&s.name, t.id, &mut |t| {
                t.created_ms += 1;
                Ok(())
            })
            .unwrap();
        });
    }
    {
        let wal = WalDatastore::open(tmp("crud")).unwrap();
        let s = wal.create_study(study("w")).unwrap();
        bench("wal (buffered):  create_trial + mutate", || {
            let t = wal.create_trial(&s.name, TrialProto::default()).unwrap();
            wal.mutate_trial(&s.name, t.id, &mut |t| {
                t.created_ms += 1;
                Ok(())
            })
            .unwrap();
        });
    }
    {
        let wal = WalDatastore::open_with_sync(tmp("sync"), true).unwrap();
        let s = wal.create_study(study("ws")).unwrap();
        bench("wal (fsync/write): create_trial + mutate", || {
            let t = wal.create_trial(&s.name, TrialProto::default()).unwrap();
            wal.mutate_trial(&s.name, t.id, &mut |t| {
                t.created_ms += 1;
                Ok(())
            })
            .unwrap();
        });
    }

    section("C-DS: read path");
    let mem = InMemoryDatastore::new();
    let s = mem.create_study(study("reads")).unwrap();
    for _ in 0..10_000 {
        mem.create_trial(&s.name, TrialProto::default()).unwrap();
    }
    bench("get_trial from 10k-trial study", || {
        std::hint::black_box(mem.get_trial(&s.name, 5000).unwrap());
    });
    bench("list_trials (10k trials, full clone)", || {
        std::hint::black_box(mem.list_trials(&s.name).unwrap());
    });

    section("C-DS: WAL recovery (server-side fault-tolerance cost)");
    for &n in &[1_000usize, 10_000, 50_000] {
        let path = tmp(&format!("recovery-{n}"));
        {
            let wal = WalDatastore::open(&path).unwrap();
            let s = wal.create_study(study("r")).unwrap();
            for _ in 0..n {
                wal.create_trial(&s.name, TrialProto::default()).unwrap();
            }
        }
        let size_mb = std::fs::metadata(&path).unwrap().len() as f64 / 1e6;
        let sw = Stopwatch::start();
        let wal = WalDatastore::open(&path).unwrap();
        let ms = sw.elapsed_millis_f64();
        assert_eq!(wal.trial_count("studies/1").unwrap(), n);
        note(&format!("replay {n:>6} trials ({size_mb:>6.2} MB log): {ms:>8.2} ms"));
    }

    section("C-DS: compaction");
    let path = tmp("compact");
    let wal = WalDatastore::open(&path).unwrap();
    let s = wal.create_study(study("c")).unwrap();
    let t = wal.create_trial(&s.name, TrialProto::default()).unwrap();
    for i in 0..20_000 {
        wal.mutate_trial(&s.name, t.id, &mut |t| {
            t.created_ms = i;
            Ok(())
        })
        .unwrap();
    }
    let before = wal.log_size();
    let sw = Stopwatch::start();
    wal.compact().unwrap();
    note(&format!(
        "compaction of 20k-update log: {} -> {} bytes in {:.2} ms",
        before,
        wal.log_size(),
        sw.elapsed_millis_f64()
    ));

    // ------------------------------------------------------------------
    // C-DS-MT: the paper's "multiple parallel evaluations" load pattern.
    // N worker threads hammer create_trial + mutate_trial, one study per
    // thread (distinct studies route to distinct shards).
    // ------------------------------------------------------------------
    const MT_THREADS: usize = 8;

    section("C-DS-MT: in-memory contention, 8 threads x (create_trial + mutate)");
    let run_mem = |ds: Arc<InMemoryDatastore>, per_thread: usize| -> f64 {
        let studies: Vec<String> = (0..MT_THREADS)
            .map(|i| ds.create_study(study(&format!("mt{i}"))).unwrap().name)
            .collect();
        let sw = Stopwatch::start();
        let handles: Vec<_> = studies
            .into_iter()
            .map(|name| {
                let ds = Arc::clone(&ds);
                std::thread::spawn(move || {
                    for _ in 0..per_thread {
                        let t = ds.create_trial(&name, TrialProto::default()).unwrap();
                        ds.mutate_trial(&name, t.id, &mut |t| {
                            t.created_ms += 1;
                            Ok(())
                        })
                        .unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        sw.elapsed_millis_f64()
    };
    let per_thread = 5_000;
    let ops = (MT_THREADS * per_thread * 2) as f64;
    let single_ms = run_mem(Arc::new(InMemoryDatastore::with_shards(1)), per_thread);
    let sharded_ms = run_mem(Arc::new(InMemoryDatastore::new()), per_thread);
    note(&format!(
        "single lock (1 shard):  {single_ms:>8.2} ms  ({:>9.0} ops/s)",
        ops / (single_ms / 1e3)
    ));
    note(&format!(
        "sharded (16 shards):    {sharded_ms:>8.2} ms  ({:>9.0} ops/s)  speedup {:.2}x",
        ops / (sharded_ms / 1e3),
        single_ms / sharded_ms
    ));
    // Timing comparisons are advisory on shared/noisy runners: set
    // OSSVIZIER_BENCH_LAX=1 (as PR CI does) to report without failing;
    // the nightly soak job enforces them.
    check(
        "sharded-vs-single-lock",
        sharded_ms <= single_ms * 1.15,
        &format!(
            "sharded store must not lose to the single-lock baseline \
             ({sharded_ms:.2} ms vs {single_ms:.2} ms)"
        ),
    );

    section("C-DS-MT: WAL fsync contention, 8 threads x create_trial");
    let run_wal = |opts: WalOptions, tag: &str, per_thread: usize| -> (f64, u64, u64) {
        let ds = Arc::new(WalDatastore::open_with_options(tmp(tag), opts).unwrap());
        let studies: Vec<String> = (0..MT_THREADS)
            .map(|i| ds.create_study(study(&format!("w{i}"))).unwrap().name)
            .collect();
        let sw = Stopwatch::start();
        let handles: Vec<_> = studies
            .into_iter()
            .map(|name| {
                let ds = Arc::clone(&ds);
                std::thread::spawn(move || {
                    for _ in 0..per_thread {
                        ds.create_trial(&name, TrialProto::default()).unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        (sw.elapsed_millis_f64(), ds.records_flushed(), ds.batches_flushed())
    };
    let per_thread = 250;
    let ops = (MT_THREADS * per_thread) as f64;
    let (serial_ms, _, _) = run_wal(
        WalOptions { sync: true, group_commit: false, ..WalOptions::default() },
        "mt-serial",
        per_thread,
    );
    let (group_ms, recs, batches) = run_wal(
        WalOptions { sync: true, ..WalOptions::default() },
        "mt-group",
        per_thread,
    );
    note(&format!(
        "serial fsync/write:     {serial_ms:>8.2} ms  ({:>9.0} ops/s)",
        ops / (serial_ms / 1e3)
    ));
    note(&format!(
        "group commit + fsync:   {group_ms:>8.2} ms  ({:>9.0} ops/s)  speedup {:.2}x, \
         {recs} records in {batches} fsync batches ({:.1} rec/batch)",
        ops / (group_ms / 1e3),
        serial_ms / group_ms,
        recs as f64 / batches.max(1) as f64
    ));
    check(
        "group-commit-vs-serial-fsync",
        group_ms <= serial_ms * 1.15,
        &format!(
            "group commit must not lose to serial fsync under contention \
             ({group_ms:.2} ms vs {serial_ms:.2} ms)"
        ),
    );

    bench_snap();
    finish("DATASTORE");
}
