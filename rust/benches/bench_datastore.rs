//! C-DS: datastore performance — in-memory vs WAL-durable CRUD, WAL
//! recovery time (the cost of server-side fault tolerance), the effect of
//! log compaction, and multi-threaded contention (sharding vs a single
//! lock; WAL group commit vs serial fsync).

use ossvizier::datastore::memory::InMemoryDatastore;
use ossvizier::datastore::wal::{WalDatastore, WalOptions};
use ossvizier::datastore::Datastore;
use ossvizier::util::benchkit::{bench, check, finish, note, section};
use ossvizier::util::time::Stopwatch;
use ossvizier::wire::messages::{StudyProto, TrialProto};
use std::sync::Arc;

fn tmp(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("ossvizier-bench-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d.join("store.wal")
}

fn study(name: &str) -> StudyProto {
    StudyProto { display_name: name.into(), ..Default::default() }
}

fn main() {
    section("C-DS: trial create+complete cycle");
    {
        let mem = InMemoryDatastore::new();
        let s = mem.create_study(study("m")).unwrap();
        bench("in-memory: create_trial + mutate", || {
            let t = mem.create_trial(&s.name, TrialProto::default()).unwrap();
            mem.mutate_trial(&s.name, t.id, &mut |t| {
                t.created_ms += 1;
                Ok(())
            })
            .unwrap();
        });
    }
    {
        let wal = WalDatastore::open(tmp("crud")).unwrap();
        let s = wal.create_study(study("w")).unwrap();
        bench("wal (buffered):  create_trial + mutate", || {
            let t = wal.create_trial(&s.name, TrialProto::default()).unwrap();
            wal.mutate_trial(&s.name, t.id, &mut |t| {
                t.created_ms += 1;
                Ok(())
            })
            .unwrap();
        });
    }
    {
        let wal = WalDatastore::open_with_sync(tmp("sync"), true).unwrap();
        let s = wal.create_study(study("ws")).unwrap();
        bench("wal (fsync/write): create_trial + mutate", || {
            let t = wal.create_trial(&s.name, TrialProto::default()).unwrap();
            wal.mutate_trial(&s.name, t.id, &mut |t| {
                t.created_ms += 1;
                Ok(())
            })
            .unwrap();
        });
    }

    section("C-DS: read path");
    let mem = InMemoryDatastore::new();
    let s = mem.create_study(study("reads")).unwrap();
    for _ in 0..10_000 {
        mem.create_trial(&s.name, TrialProto::default()).unwrap();
    }
    bench("get_trial from 10k-trial study", || {
        std::hint::black_box(mem.get_trial(&s.name, 5000).unwrap());
    });
    bench("list_trials (10k trials, full clone)", || {
        std::hint::black_box(mem.list_trials(&s.name).unwrap());
    });

    section("C-DS: WAL recovery (server-side fault-tolerance cost)");
    for &n in &[1_000usize, 10_000, 50_000] {
        let path = tmp(&format!("recovery-{n}"));
        {
            let wal = WalDatastore::open(&path).unwrap();
            let s = wal.create_study(study("r")).unwrap();
            for _ in 0..n {
                wal.create_trial(&s.name, TrialProto::default()).unwrap();
            }
        }
        let size_mb = std::fs::metadata(&path).unwrap().len() as f64 / 1e6;
        let sw = Stopwatch::start();
        let wal = WalDatastore::open(&path).unwrap();
        let ms = sw.elapsed_millis_f64();
        assert_eq!(wal.trial_count("studies/1").unwrap(), n);
        note(&format!("replay {n:>6} trials ({size_mb:>6.2} MB log): {ms:>8.2} ms"));
    }

    section("C-DS: compaction");
    let path = tmp("compact");
    let wal = WalDatastore::open(&path).unwrap();
    let s = wal.create_study(study("c")).unwrap();
    let t = wal.create_trial(&s.name, TrialProto::default()).unwrap();
    for i in 0..20_000 {
        wal.mutate_trial(&s.name, t.id, &mut |t| {
            t.created_ms = i;
            Ok(())
        })
        .unwrap();
    }
    let before = wal.log_size();
    let sw = Stopwatch::start();
    wal.compact().unwrap();
    note(&format!(
        "compaction of 20k-update log: {} -> {} bytes in {:.2} ms",
        before,
        wal.log_size(),
        sw.elapsed_millis_f64()
    ));

    // ------------------------------------------------------------------
    // C-DS-MT: the paper's "multiple parallel evaluations" load pattern.
    // N worker threads hammer create_trial + mutate_trial, one study per
    // thread (distinct studies route to distinct shards).
    // ------------------------------------------------------------------
    const MT_THREADS: usize = 8;

    section("C-DS-MT: in-memory contention, 8 threads x (create_trial + mutate)");
    let run_mem = |ds: Arc<InMemoryDatastore>, per_thread: usize| -> f64 {
        let studies: Vec<String> = (0..MT_THREADS)
            .map(|i| ds.create_study(study(&format!("mt{i}"))).unwrap().name)
            .collect();
        let sw = Stopwatch::start();
        let handles: Vec<_> = studies
            .into_iter()
            .map(|name| {
                let ds = Arc::clone(&ds);
                std::thread::spawn(move || {
                    for _ in 0..per_thread {
                        let t = ds.create_trial(&name, TrialProto::default()).unwrap();
                        ds.mutate_trial(&name, t.id, &mut |t| {
                            t.created_ms += 1;
                            Ok(())
                        })
                        .unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        sw.elapsed_millis_f64()
    };
    let per_thread = 5_000;
    let ops = (MT_THREADS * per_thread * 2) as f64;
    let single_ms = run_mem(Arc::new(InMemoryDatastore::with_shards(1)), per_thread);
    let sharded_ms = run_mem(Arc::new(InMemoryDatastore::new()), per_thread);
    note(&format!(
        "single lock (1 shard):  {single_ms:>8.2} ms  ({:>9.0} ops/s)",
        ops / (single_ms / 1e3)
    ));
    note(&format!(
        "sharded (16 shards):    {sharded_ms:>8.2} ms  ({:>9.0} ops/s)  speedup {:.2}x",
        ops / (sharded_ms / 1e3),
        single_ms / sharded_ms
    ));
    // Timing comparisons are advisory on shared/noisy runners: set
    // OSSVIZIER_BENCH_LAX=1 (as PR CI does) to report without failing;
    // the nightly soak job enforces them.
    check(
        "sharded-vs-single-lock",
        sharded_ms <= single_ms * 1.15,
        &format!(
            "sharded store must not lose to the single-lock baseline \
             ({sharded_ms:.2} ms vs {single_ms:.2} ms)"
        ),
    );

    section("C-DS-MT: WAL fsync contention, 8 threads x create_trial");
    let run_wal = |opts: WalOptions, tag: &str, per_thread: usize| -> (f64, u64, u64) {
        let ds = Arc::new(WalDatastore::open_with_options(tmp(tag), opts).unwrap());
        let studies: Vec<String> = (0..MT_THREADS)
            .map(|i| ds.create_study(study(&format!("w{i}"))).unwrap().name)
            .collect();
        let sw = Stopwatch::start();
        let handles: Vec<_> = studies
            .into_iter()
            .map(|name| {
                let ds = Arc::clone(&ds);
                std::thread::spawn(move || {
                    for _ in 0..per_thread {
                        ds.create_trial(&name, TrialProto::default()).unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        (sw.elapsed_millis_f64(), ds.records_flushed(), ds.batches_flushed())
    };
    let per_thread = 250;
    let ops = (MT_THREADS * per_thread) as f64;
    let (serial_ms, _, _) = run_wal(
        WalOptions { sync: true, group_commit: false, ..WalOptions::default() },
        "mt-serial",
        per_thread,
    );
    let (group_ms, recs, batches) = run_wal(
        WalOptions { sync: true, ..WalOptions::default() },
        "mt-group",
        per_thread,
    );
    note(&format!(
        "serial fsync/write:     {serial_ms:>8.2} ms  ({:>9.0} ops/s)",
        ops / (serial_ms / 1e3)
    ));
    note(&format!(
        "group commit + fsync:   {group_ms:>8.2} ms  ({:>9.0} ops/s)  speedup {:.2}x, \
         {recs} records in {batches} fsync batches ({:.1} rec/batch)",
        ops / (group_ms / 1e3),
        serial_ms / group_ms,
        recs as f64 / batches.max(1) as f64
    ));
    check(
        "group-commit-vs-serial-fsync",
        group_ms <= serial_ms * 1.15,
        &format!(
            "group commit must not lose to serial fsync under contention \
             ({group_ms:.2} ms vs {serial_ms:.2} ms)"
        ),
    );
    finish("DATASTORE");
}
