//! C-DS: datastore performance — in-memory vs WAL-durable CRUD, WAL
//! recovery time (the cost of server-side fault tolerance), and the
//! effect of log compaction.

use ossvizier::datastore::memory::InMemoryDatastore;
use ossvizier::datastore::wal::WalDatastore;
use ossvizier::datastore::Datastore;
use ossvizier::util::benchkit::{bench, note, section};
use ossvizier::util::time::Stopwatch;
use ossvizier::wire::messages::{StudyProto, TrialProto};

fn tmp(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("ossvizier-bench-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d.join("store.wal")
}

fn study(name: &str) -> StudyProto {
    StudyProto { display_name: name.into(), ..Default::default() }
}

fn main() {
    section("C-DS: trial create+complete cycle");
    {
        let mem = InMemoryDatastore::new();
        let s = mem.create_study(study("m")).unwrap();
        bench("in-memory: create_trial + mutate", || {
            let t = mem.create_trial(&s.name, TrialProto::default()).unwrap();
            mem.mutate_trial(&s.name, t.id, &mut |t| {
                t.created_ms += 1;
                Ok(())
            })
            .unwrap();
        });
    }
    {
        let wal = WalDatastore::open(tmp("crud")).unwrap();
        let s = wal.create_study(study("w")).unwrap();
        bench("wal (buffered):  create_trial + mutate", || {
            let t = wal.create_trial(&s.name, TrialProto::default()).unwrap();
            wal.mutate_trial(&s.name, t.id, &mut |t| {
                t.created_ms += 1;
                Ok(())
            })
            .unwrap();
        });
    }
    {
        let wal = WalDatastore::open_with_sync(tmp("sync"), true).unwrap();
        let s = wal.create_study(study("ws")).unwrap();
        bench("wal (fsync/write): create_trial + mutate", || {
            let t = wal.create_trial(&s.name, TrialProto::default()).unwrap();
            wal.mutate_trial(&s.name, t.id, &mut |t| {
                t.created_ms += 1;
                Ok(())
            })
            .unwrap();
        });
    }

    section("C-DS: read path");
    let mem = InMemoryDatastore::new();
    let s = mem.create_study(study("reads")).unwrap();
    for _ in 0..10_000 {
        mem.create_trial(&s.name, TrialProto::default()).unwrap();
    }
    bench("get_trial from 10k-trial study", || {
        std::hint::black_box(mem.get_trial(&s.name, 5000).unwrap());
    });
    bench("list_trials (10k trials, full clone)", || {
        std::hint::black_box(mem.list_trials(&s.name).unwrap());
    });

    section("C-DS: WAL recovery (server-side fault-tolerance cost)");
    for &n in &[1_000usize, 10_000, 50_000] {
        let path = tmp(&format!("recovery-{n}"));
        {
            let wal = WalDatastore::open(&path).unwrap();
            let s = wal.create_study(study("r")).unwrap();
            for _ in 0..n {
                wal.create_trial(&s.name, TrialProto::default()).unwrap();
            }
        }
        let size_mb = std::fs::metadata(&path).unwrap().len() as f64 / 1e6;
        let sw = Stopwatch::start();
        let wal = WalDatastore::open(&path).unwrap();
        let ms = sw.elapsed_millis_f64();
        assert_eq!(wal.trial_count("studies/1").unwrap(), n);
        note(&format!("replay {n:>6} trials ({size_mb:>6.2} MB log): {ms:>8.2} ms"));
    }

    section("C-DS: compaction");
    let path = tmp("compact");
    let wal = WalDatastore::open(&path).unwrap();
    let s = wal.create_study(study("c")).unwrap();
    let t = wal.create_trial(&s.name, TrialProto::default()).unwrap();
    for i in 0..20_000 {
        wal.mutate_trial(&s.name, t.id, &mut |t| {
            t.created_ms = i;
            Ok(())
        })
        .unwrap();
    }
    let before = wal.log_size();
    let sw = Stopwatch::start();
    wal.compact().unwrap();
    note(&format!(
        "compaction of 20k-update log: {} -> {} bytes in {:.2} ms",
        before,
        wal.log_size(),
        sw.elapsed_millis_f64()
    ));
}
