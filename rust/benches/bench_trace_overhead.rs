//! C-TRACE: what request tracing costs on the serving path. The tracing
//! config latches process-wide, so the traced and untraced servers each
//! run in a child process (this binary re-execs itself in a serve-only
//! mode) while the parent — whose own tracing stays off — measures ping
//! RTT against both over real TCP:
//!
//! * disabled (the default): the strict claim. The span hooks reduce to
//!   one cached boolean load, so the RTT must not regress — the
//!   `rtt_*_disabled` metric is the one `bench_baselines/` enforces.
//! * enabled at sample rate 1.0: the lax claim (shared runners are too
//!   noisy to enforce a few-percent bound): RTT stays within 5% of the
//!   disabled run.
//!
//! Structural zero-cost is asserted strictly either way: a process that
//! never enables tracing records no spans and allocates no rings, and
//! each child's `GetTraces` surface proves the mode it actually ran in.
//!
//! Results land in `BENCH_TRACE_OVERHEAD.json` at the repo root (see
//! `bench_baselines/README.md` for the comparison gate).

use ossvizier::client::transport::{call, TcpTransport};
use ossvizier::client::LocalTransport;
use ossvizier::service::{in_memory_service, VizierServer};
use ossvizier::util::benchkit::{bench, check, check_strict, finish, note, section};
use ossvizier::util::trace;
use ossvizier::wire::framing::Method;
use ossvizier::wire::messages::{EmptyResponse, GetTracesRequest, GetTracesResponse};
use std::io::{BufRead, BufReader, Write};
use std::process::{Child, Command, Stdio};

/// Set in the re-exec'd child: serve on a loopback port until stdin
/// closes. The child's tracing mode comes from `OSSVIZIER_TRACE`, which
/// the parent sets per child.
const SERVER_MODE_VAR: &str = "OSSVIZIER_BENCH_TRACE_SERVER";

/// Pings per measured round (one `bench` sample = one round).
const PINGS_PER_ROUND: usize = 100;

fn serve_until_stdin_closes() -> ! {
    let server = VizierServer::start(in_memory_service(2), "127.0.0.1:0").unwrap();
    println!("ADDR={}", server.local_addr());
    std::io::stdout().flush().unwrap();
    let mut line = String::new();
    let _ = std::io::stdin().read_line(&mut line); // EOF = parent is done
    server.shutdown();
    std::process::exit(0);
}

/// Re-exec this binary as a server child; returns the child and the
/// address it bound. `trace` is the child's `OSSVIZIER_TRACE` value
/// (`None` = unset, the disabled default).
fn spawn_server(trace_env: Option<&str>) -> (Child, String) {
    let exe = std::env::current_exe().unwrap();
    let mut cmd = Command::new(exe);
    cmd.env(SERVER_MODE_VAR, "1")
        .env_remove("OSSVIZIER_TRACE")
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::inherit());
    if let Some(rate) = trace_env {
        cmd.env("OSSVIZIER_TRACE", rate);
    }
    let mut child = cmd.spawn().expect("re-exec server child");
    let mut reader = BufReader::new(child.stdout.take().expect("child stdout"));
    let mut line = String::new();
    reader.read_line(&mut line).expect("child address line");
    let addr = line
        .trim()
        .strip_prefix("ADDR=")
        .expect("server child must print ADDR=<addr>")
        .to_string();
    (child, addr)
}

fn stop_server(mut child: Child) {
    drop(child.stdin.take()); // EOF tells the child to shut down
    let _ = child.wait();
}

fn ping(t: &mut TcpTransport) {
    let _: EmptyResponse = call(t, Method::Ping, &EmptyResponse::default()).unwrap();
}

fn trace_count(t: &mut TcpTransport) -> usize {
    let resp: GetTracesResponse = call(
        t,
        Method::GetTraces,
        &GetTracesRequest { limit: 0, include_infra: false },
    )
    .unwrap();
    resp.traces.len()
}

fn main() {
    if std::env::var_os(SERVER_MODE_VAR).is_some() {
        serve_until_stdin_closes();
    }

    // ------------------------------------------------------------------
    // Structural zero-cost: this parent process never enables tracing, so
    // after a warm round trip through the full dispatch path there must
    // be no spans, no rings, nothing. These hold on any hardware, so they
    // are strict even under OSSVIZIER_BENCH_LAX.
    // ------------------------------------------------------------------
    section("C-TRACE: disabled mode is structurally free");
    if std::env::var_os("OSSVIZIER_TRACE").is_some() {
        note("OSSVIZIER_TRACE is set in this environment; skipping the disabled-mode checks");
    } else {
        let mut local = LocalTransport::new(in_memory_service(2));
        for _ in 0..PINGS_PER_ROUND {
            let _: EmptyResponse =
                call(&mut local, Method::Ping, &EmptyResponse::default()).unwrap();
        }
        check_strict(
            "disabled-tracing-stays-off",
            !trace::enabled(),
            "trace::enabled() is false without init or OSSVIZIER_TRACE",
        );
        check_strict(
            "disabled-records-no-spans",
            trace::snapshot().is_empty(),
            &format!(
                "{} spans recorded after {PINGS_PER_ROUND} dispatches",
                trace::snapshot().len()
            ),
        );
        check_strict(
            "disabled-allocates-no-rings",
            trace::registered_rings() == 0,
            &format!("{} span rings registered", trace::registered_rings()),
        );
    }

    // ------------------------------------------------------------------
    // RTT with tracing off vs on, each mode in its own server process.
    // ------------------------------------------------------------------
    section(&format!(
        "C-TRACE: ping RTT over TCP, {PINGS_PER_ROUND} pings/round, traced vs untraced server"
    ));

    let (child_off, addr_off) = spawn_server(None);
    let mut t_off = TcpTransport::connect(&addr_off).unwrap();
    let off = bench(&format!("trace_overhead/rtt_{PINGS_PER_ROUND}pings_disabled"), || {
        for _ in 0..PINGS_PER_ROUND {
            ping(&mut t_off);
        }
    });
    check_strict(
        "untraced-server-has-no-traces",
        trace_count(&mut t_off) == 0,
        "GetTraces empty on the untraced child",
    );
    stop_server(child_off);

    let (child_on, addr_on) = spawn_server(Some("1"));
    let mut t_on = TcpTransport::connect(&addr_on).unwrap();
    let on = bench(&format!("trace_overhead/rtt_{PINGS_PER_ROUND}pings_enabled"), || {
        for _ in 0..PINGS_PER_ROUND {
            ping(&mut t_on);
        }
    });
    check_strict(
        "traced-server-recorded-traces",
        trace_count(&mut t_on) > 0,
        "GetTraces non-empty on the traced child",
    );
    stop_server(child_on);

    let ratio = on.mean.as_secs_f64() / off.mean.as_secs_f64().max(f64::MIN_POSITIVE);
    note(&format!(
        "rtt/ping: disabled {:.1} us, enabled {:.1} us ({:+.1}%)",
        off.mean_us() / PINGS_PER_ROUND as f64,
        on.mean_us() / PINGS_PER_ROUND as f64,
        (ratio - 1.0) * 100.0,
    ));
    // Timing comparison: lax (`check`) because loopback RTT on shared
    // runners jitters more than the effect being bounded.
    check(
        "enabled-overhead-within-5pct",
        ratio <= 1.05,
        &format!("enabled/disabled RTT ratio {ratio:.3} <= 1.05"),
    );

    finish("TRACE_OVERHEAD");
}
