//! B1/B2 + C-MO support benches: cost of the automated-stopping rules vs
//! pool size, and Pareto-frontier extraction scaling (the
//! `ListOptimalTrials` hot path).

use ossvizier::pyvizier::pareto::{non_dominated_ranks, optimal_trials, pareto_front_indices};
use ossvizier::pyvizier::{
    Measurement, MetricInformation, ParameterDict, StudyConfig, Trial, TrialState,
};
use ossvizier::stopping;
use ossvizier::util::benchkit::{bench, finish, section};
use ossvizier::util::rng::Pcg32;
use ossvizier::wire::messages::{MetricGoal, StoppingConfig, StoppingKind};

fn curve_trial(id: u64, rng: &mut Pcg32, steps: i64) -> Trial {
    let plateau = 0.5 + 0.4 * rng.f64();
    let mut t = Trial::new(id, ParameterDict::new());
    for s in 1..=steps {
        let acc = plateau * (1.0 - (-(s as f64) / 5.0).exp());
        t.measurements.push(Measurement::new(s).with_metric("acc", acc));
    }
    t.state = TrialState::Completed;
    t.final_measurement = t.measurements.last().cloned();
    t
}

fn main() {
    section("B1/B2: early-stopping decision latency vs completed-pool size");
    let mut rng = Pcg32::seeded(4);
    for &n in &[10usize, 100, 1000] {
        let pool: Vec<Trial> = (0..n as u64).map(|i| curve_trial(i, &mut rng, 20)).collect();
        let pending = curve_trial(9999, &mut rng, 10);
        for (kind, label) in [(StoppingKind::Median, "median"), (StoppingKind::DecayCurve, "decay")] {
            let mut config = StudyConfig::new("b");
            config.add_metric(MetricInformation::maximize("acc"));
            config.stopping = StoppingConfig { kind, min_trials: 3, confidence: 1.64 };
            bench(&format!("{label:<7} rule, pool n={n:<5}"), || {
                std::hint::black_box(stopping::decide(&config, &pending, &pool));
            });
        }
    }

    section("C-MO: Pareto-frontier extraction scaling");
    for &n in &[100usize, 1000, 5000] {
        for &k in &[2usize, 4] {
            let pts: Vec<Vec<f64>> = (0..n)
                .map(|_| (0..k).map(|_| rng.f64()).collect())
                .collect();
            bench(&format!("pareto front    n={n:<5} k={k}"), || {
                std::hint::black_box(pareto_front_indices(&pts));
            });
            if n <= 1000 {
                bench(&format!("nsga2 ranks     n={n:<5} k={k}"), || {
                    std::hint::black_box(non_dominated_ranks(&pts));
                });
            }
        }
    }

    section("C-MO: ListOptimalTrials end-to-end (trial conversion included)");
    let metrics = vec![
        MetricInformation::maximize("f1"),
        MetricInformation {
            name: "f2".into(),
            goal: MetricGoal::Minimize,
            min_value: 0.0,
            max_value: 1.0,
        },
    ];
    let trials: Vec<Trial> = (0..2000u64)
        .map(|i| {
            let mut t = Trial::new(i, ParameterDict::new());
            t.state = TrialState::Completed;
            t.final_measurement = Some(
                Measurement::new(1)
                    .with_metric("f1", rng.f64())
                    .with_metric("f2", rng.f64()),
            );
            t
        })
        .collect();
    bench("optimal_trials over 2000 completed", || {
        std::hint::black_box(optimal_trials(&trials, &metrics));
    });
    finish("STOPPING_PARETO");
}
