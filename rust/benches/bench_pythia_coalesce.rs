//! C-PYTHIA-COAL: coalesced vs per-operation policy invocation for K
//! clients sharing one study (Pythia v2, ROADMAP "batch suggest
//! operations per study").
//!
//! K worker threads hammer one study with suggest requests through the
//! in-process transport. With coalescing ON (the default), suggest
//! operations queued behind a busy worker share one policy invocation;
//! with coalescing OFF (the pre-v2 baseline) every operation pays its own
//! policy run — for GP bandit, its own GP fit.
//!
//! Run with OSSVIZIER_BENCH_LAX=1 to report without asserting (noisy
//! shared machines); locally the assertions are enforced.

use ossvizier::client::{LocalTransport, VizierClient};
use ossvizier::datastore::memory::InMemoryDatastore;
use ossvizier::datastore::Datastore;
use ossvizier::pythia::policy::{Policy, PolicyError, SuggestDecision, SuggestRequest};
use ossvizier::pythia::supporter::PolicySupporter;
use ossvizier::pyvizier::{
    converters, Algorithm, Measurement, MetricInformation, StudyConfig, Trial, TrialSuggestion,
};
use ossvizier::service::build_service;
use ossvizier::util::benchkit::{check, finish, section};
use ossvizier::util::rng::Pcg32;
use ossvizier::util::time::Stopwatch;
use ossvizier::wire::messages::{ScaleType, StudyProto, TrialState};
use std::sync::{Arc, Barrier};

const K: usize = 8; // concurrent clients on one study
const ROUNDS: usize = 5; // suggest+complete rounds per client
const WORKERS: usize = 2; // policy worker threads (< K so ops queue up)

fn config(algorithm: Algorithm) -> StudyConfig {
    let mut c = StudyConfig::new("coal-bench");
    c.search_space
        .add_float("lr", 1e-4, 1e-1, ScaleType::Log)
        .add_int("layers", 1, 5);
    c.add_metric(MetricInformation::maximize("score"));
    c.algorithm = algorithm;
    c.seed = 11;
    c
}

fn objective(t: &Trial) -> f64 {
    let lr = t.parameters.get_f64("lr").unwrap_or(1e-2);
    let layers = t.parameters.get_i64("layers").unwrap_or(3) as f64;
    -(lr.log10() + 2.0).powi(2) - 0.1 * (layers - 3.0).powi(2)
}

/// A deliberately non-free policy: sleeps ~2ms (standing in for any real
/// model fit), then samples uniformly. Makes the queueing dynamics of an
/// expensive policy visible even on fast machines.
struct SlowRandomPolicy;

impl Policy for SlowRandomPolicy {
    fn suggest(
        &mut self,
        req: &SuggestRequest,
        supporter: &dyn PolicySupporter,
    ) -> Result<SuggestDecision, PolicyError> {
        std::thread::sleep(std::time::Duration::from_millis(2));
        let salt = supporter.trial_count(&req.study_name)? as u64;
        let mut rng = Pcg32::seeded(req.study_config.seed ^ salt.wrapping_add(1));
        let suggestions = (0..req.total_count())
            .map(|_| TrialSuggestion::new(req.study_config.search_space.sample(&mut rng)))
            .collect();
        Ok(SuggestDecision::from_flat(req, suggestions))
    }
    fn name(&self) -> &str {
        "slow-random"
    }
}

struct CaseResult {
    policy_runs: u64,
    ops: u64,
    secs: f64,
}

fn run_case(algorithm: Algorithm, warmup: usize, coalescing: bool) -> CaseResult {
    let ds: Arc<dyn Datastore> = Arc::new(InMemoryDatastore::new());
    let cfg = config(algorithm);
    let study = ds
        .create_study(StudyProto {
            display_name: "coal-bench".into(),
            spec: converters::study_config_to_proto(&cfg),
            ..Default::default()
        })
        .unwrap();
    // Warm the study so model-based policies do real fits.
    let mut rng = Pcg32::seeded(3);
    for _ in 0..warmup {
        let mut t = Trial::new(0, cfg.search_space.sample(&mut rng));
        t.state = TrialState::Completed;
        let score = objective(&t);
        t.final_measurement = Some(Measurement::new(1).with_metric("score", score));
        ds.create_trial(&study.name, converters::trial_to_proto(&t)).unwrap();
    }

    let service = build_service(
        Arc::clone(&ds),
        |reg| reg.register("SLOW_RANDOM", Arc::new(|_| Box::new(SlowRandomPolicy))),
        WORKERS,
    );
    service.set_suggest_coalescing(coalescing);

    let barrier = Arc::new(Barrier::new(K));
    let sw = Stopwatch::start();
    let handles: Vec<_> = (0..K)
        .map(|i| {
            let service = Arc::clone(&service);
            let barrier = Arc::clone(&barrier);
            let study_name = study.name.clone();
            std::thread::spawn(move || {
                let transport = Box::new(LocalTransport::new(service));
                let mut client =
                    VizierClient::for_study(transport, &study_name, &format!("w{i}"));
                barrier.wait();
                for _ in 0..ROUNDS {
                    let trial = client.get_suggestions(1).expect("suggest").remove(0);
                    let m = Measurement::new(1).with_metric("score", objective(&trial));
                    client.complete_trial(trial.id, Some(&m)).expect("complete");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let secs = sw.elapsed().as_secs_f64();
    let result = CaseResult {
        policy_runs: service.metrics.policy_runs(),
        ops: service.metrics.suggest_ops_served(),
        secs,
    };
    service.shutdown();
    result
}

fn report(label: &str, on: &CaseResult, off: &CaseResult) {
    println!(
        "{label:<16} coalesced: {:>3} policy runs / {:>3} ops in {:>6.3}s   \
         per-op: {:>3} policy runs / {:>3} ops in {:>6.3}s   ({:.2}x fewer runs)",
        on.policy_runs,
        on.ops,
        on.secs,
        off.policy_runs,
        off.ops,
        off.secs,
        off.policy_runs as f64 / on.policy_runs.max(1) as f64,
    );
}

fn main() {
    section("C-PYTHIA-COAL: coalesced vs per-op policy invocations, K=8 clients, one study");

    // Random (wrapped with a 2ms fit cost stand-in).
    let on = run_case(Algorithm::Custom("SLOW_RANDOM".into()), 0, true);
    let off = run_case(Algorithm::Custom("SLOW_RANDOM".into()), 0, false);
    report("random", &on, &off);
    check(
        "random-per-op-baseline",
        off.policy_runs == off.ops,
        &format!("per-op baseline: one run per op ({} runs / {} ops)", off.policy_runs, off.ops),
    );
    check(
        "random-coalesces",
        on.policy_runs < on.ops && on.policy_runs < off.policy_runs,
        &format!(
            "coalescing must serve {} ops with fewer runs than per-op (got {} vs {})",
            on.ops, on.policy_runs, off.policy_runs
        ),
    );

    // GP bandit (pure-Rust backend): each policy run is a real GP fit.
    let on = run_case(Algorithm::Custom("GP_BANDIT_RUST".into()), 30, true);
    let off = run_case(Algorithm::Custom("GP_BANDIT_RUST".into()), 30, false);
    report("gp_bandit", &on, &off);
    check(
        "gp-per-op-baseline",
        off.policy_runs == off.ops,
        &format!("per-op baseline: one run per op ({} runs / {} ops)", off.policy_runs, off.ops),
    );
    check(
        "gp-coalesces",
        on.policy_runs < on.ops && on.policy_runs <= off.policy_runs,
        &format!(
            "coalescing must serve {} ops with fewer GP fits (got {} vs per-op {})",
            on.ops, on.policy_runs, off.policy_runs
        ),
    );
    finish("PYTHIA_COALESCE");
}
