//! Front-end behaviour over real TCP: resumable framing under slow or
//! malicious clients, bounded pool threads with many idle connections,
//! the `active_connections` gauge, and graceful shutdown that joins
//! every front-end thread (regression tests for the historical
//! `vizier-conn` thread leak in both server modes).

use ossvizier::pythia::runner::default_registry;
use ossvizier::service::remote_pythia::PythiaServer;
use ossvizier::service::{in_memory_service, ServerOptions, VizierServer};
use ossvizier::testing::poller_from_env;
use ossvizier::testing::procfs::threads_with_prefix;
use ossvizier::wire::framing::{read_response, write_request, FrameError, Method, Status};
use ossvizier::wire::messages::{EmptyResponse, GetStudyRequest, StudyResponse};
use std::io::{BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::{Duration, Instant};

/// Tests in this file count live threads by name via /proc, so they must
/// not overlap with each other's servers: serialize the whole file.
fn serial() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|poisoned| poisoned.into_inner())
}

fn start_pool(workers: usize) -> VizierServer {
    // poller_from_env: the CI matrix re-runs this whole file under both
    // readiness backends via OSSVIZIER_POLLER={poll,epoll}.
    VizierServer::start_with(
        in_memory_service(2),
        "127.0.0.1:0",
        ServerOptions { workers, poller: poller_from_env(), ..Default::default() },
    )
    .unwrap()
}

fn connect(server: &VizierServer) -> TcpStream {
    let s = TcpStream::connect(server.local_addr()).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    s
}

fn ping(stream: &mut TcpStream) {
    write_request(stream, Method::Ping, &EmptyResponse::default()).unwrap();
    let mut r = BufReader::new(stream.try_clone().unwrap());
    let _: EmptyResponse = read_response(&mut r).unwrap();
}

/// A partial frame followed by a stall must not occupy a pool worker:
/// with a single worker, another client's request still gets served, and
/// the stalled frame completes fine once the rest arrives (read-state
/// machine resumability).
#[test]
fn partial_frame_stall_does_not_pin_a_worker() {
    let _serial = serial();
    let server = start_pool(1);

    // Pre-encode a full GetStudy request frame (non-empty body), then
    // send it in two halves with a long stall in between.
    let mut frame = Vec::new();
    write_request(
        &mut frame,
        Method::GetStudy,
        &GetStudyRequest { name: "studies/does-not-exist".into() },
    )
    .unwrap();
    assert!(frame.len() > 8, "need a split point inside the body");

    let mut slow = connect(&server);
    slow.write_all(&frame[..8]).unwrap();
    slow.flush().unwrap();
    std::thread::sleep(Duration::from_millis(100));

    // The one and only worker must still be free to serve this.
    let start = Instant::now();
    let mut other = connect(&server);
    ping(&mut other);
    assert!(
        start.elapsed() < Duration::from_secs(5),
        "stalled partial frame pinned the single pool worker"
    );

    // Complete the stalled frame: the parked connection resumes and the
    // request is dispatched normally (NotFound proves it went through
    // decode + service, not just framing).
    slow.write_all(&frame[8..]).unwrap();
    slow.flush().unwrap();
    let mut r = BufReader::new(slow.try_clone().unwrap());
    match read_response::<_, StudyResponse>(&mut r) {
        Err(FrameError::Rpc { status: Status::NotFound, .. }) => {}
        other => panic!("expected NotFound for the resumed request, got {other:?}"),
    }

    server.shutdown();
}

/// A garbage method byte gets an error response and closes only that
/// connection; the server keeps serving everyone else.
#[test]
fn garbage_method_byte_errors_connection_not_server() {
    let _serial = serial();
    let server = start_pool(2);

    let mut bad = connect(&server);
    // Raw frame: total = 1 (just the bogus method byte), no payload.
    bad.write_all(&1u32.to_le_bytes()).unwrap();
    bad.write_all(&[222u8]).unwrap();
    bad.flush().unwrap();
    let mut r = BufReader::new(bad.try_clone().unwrap());
    match read_response::<_, EmptyResponse>(&mut r) {
        Err(FrameError::Rpc { status, message }) => {
            assert_eq!(status, Status::InvalidArgument);
            assert!(message.contains("unknown method"), "{message}");
        }
        other => panic!("expected InvalidArgument error frame, got {other:?}"),
    }
    // The server hangs up after the error frame.
    let mut byte = [0u8; 1];
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        match r.read(&mut byte) {
            Ok(0) => break, // EOF: connection closed
            Ok(_) => panic!("unexpected extra bytes after error frame"),
            Err(_) => assert!(Instant::now() < deadline, "connection never closed"),
        }
    }

    // Unaffected: new and existing connections still work.
    let mut ok = connect(&server);
    ping(&mut ok);
    server.shutdown();
}

/// Hundreds of idle connections are served by `workers + 1` threads (the
/// workers plus the event loop) and the gauge tracks the fleet.
#[test]
fn pool_thread_count_stays_bounded() {
    let _serial = serial();
    let workers = 2;
    let server = start_pool(workers);
    let mut fleet = Vec::new();
    for _ in 0..60 {
        let mut c = connect(&server);
        ping(&mut c);
        fleet.push(c);
    }
    assert_eq!(server.frontend_metrics().active_connections(), 60);
    assert_eq!(server.frontend_metrics().connections_total(), 60);
    if let Some(n) = threads_with_prefix("vizier-fe") {
        assert!(
            n <= workers + 2,
            "60 idle connections must not cost threads: {n} > {}",
            workers + 2
        );
    }
    server.shutdown();
}

/// The gauge decrements when clients disconnect (the event loop reaps
/// closed sockets), unlike the old increment-only `connections` counter.
#[test]
fn active_connections_gauge_decrements_on_disconnect() {
    let _serial = serial();
    let server = start_pool(2);
    let mut a = connect(&server);
    let mut b = connect(&server);
    ping(&mut a);
    ping(&mut b);
    assert_eq!(server.frontend_metrics().active_connections(), 2);
    drop(b);
    let deadline = Instant::now() + Duration::from_secs(10);
    while server.frontend_metrics().active_connections() != 1 {
        assert!(Instant::now() < deadline, "gauge never decremented after disconnect");
        std::thread::sleep(Duration::from_millis(5));
    }
    ping(&mut a);
    assert_eq!(server.frontend_metrics().connections_total(), 2);
    server.shutdown();
}

/// Regression: `shutdown` used to only stop the accept loop, orphaning
/// one thread per live connection. Pool mode must join the event loop
/// and every worker even with clients still connected.
#[test]
fn pool_shutdown_leaves_no_frontend_threads() {
    let _serial = serial();
    let server = start_pool(3);
    let mut fleet = Vec::new();
    for _ in 0..10 {
        let mut c = connect(&server);
        ping(&mut c);
        fleet.push(c); // still connected during shutdown
    }
    server.shutdown();
    if let Some(n) = threads_with_prefix("vizier-fe") {
        assert_eq!(n, 0, "front-end threads must be joined by shutdown");
    }
}

/// Regression: the same leak in legacy thread-per-connection mode —
/// shutdown must actively close live connections and join their threads.
#[test]
fn legacy_shutdown_joins_connection_threads() {
    let _serial = serial();
    let server = VizierServer::start_with(
        in_memory_service(2),
        "127.0.0.1:0",
        ServerOptions { legacy_threads: true, ..Default::default() },
    )
    .unwrap();
    let mut fleet = Vec::new();
    for _ in 0..10 {
        let mut c = connect(&server);
        ping(&mut c);
        fleet.push(c); // held open: threads are blocked in read
    }
    if let Some(n) = threads_with_prefix("vizier-conn") {
        assert_eq!(n, 10, "legacy mode: one thread per live connection");
    }
    assert_eq!(server.frontend_metrics().active_connections(), 10);
    server.shutdown();
    if let Some(n) = threads_with_prefix("vizier-conn") {
        assert_eq!(n, 0, "legacy shutdown must join connection threads");
    }
    if let Some(n) = threads_with_prefix("vizier-accept") {
        assert_eq!(n, 0, "accept thread must be joined too");
    }
}

/// Legacy mode still serves RPCs correctly (it remains the benchmark
/// baseline for C-FRONTEND).
#[test]
fn legacy_mode_still_serves() {
    let _serial = serial();
    let server = VizierServer::start_with(
        in_memory_service(2),
        "127.0.0.1:0",
        ServerOptions { legacy_threads: true, ..Default::default() },
    )
    .unwrap();
    let mut c = connect(&server);
    for _ in 0..5 {
        ping(&mut c);
    }
    server.shutdown();
}

/// The Pythia front-end runs on the same pool: an unknown method id is
/// answered with Unimplemented and the connection survives; shutdown
/// joins the pythia-fe threads.
#[test]
fn pythia_frontend_unknown_method_and_shutdown() {
    let _serial = serial();
    // api_addr is only dialed lazily on real policy work, so a dummy
    // address is fine for this protocol-level test.
    let server = PythiaServer::start(default_registry(), "127.0.0.1:9", "127.0.0.1:0").unwrap();
    let mut c = TcpStream::connect(server.local_addr()).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    for _ in 0..2 {
        c.write_all(&1u32.to_le_bytes()).unwrap();
        c.write_all(&[55u8]).unwrap();
        c.flush().unwrap();
        let mut r = BufReader::new(c.try_clone().unwrap());
        match read_response::<_, EmptyResponse>(&mut r) {
            Err(FrameError::Rpc { status, .. }) => assert_eq!(status, Status::Unimplemented),
            other => panic!("expected Unimplemented, got {other:?}"),
        }
    }
    assert_eq!(server.frontend_metrics().active_connections(), 1);
    server.shutdown();
    if let Some(n) = threads_with_prefix("pythia-fe") {
        assert_eq!(n, 0, "pythia front-end threads must be joined by shutdown");
    }
}
