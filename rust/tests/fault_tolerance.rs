//! Fault-tolerance integration tests (paper §3.2, experiments C-FT-S and
//! C-FT-C): server crash/restart over the durable WAL, client
//! crash/restart under client_id trial reassignment, and crash recovery
//! across the segmented-log lifecycle (rotation, torn tails, crashes at
//! every stage of a compaction).
//!
//! The WAL configuration is env-driven (`OSSVIZIER_WAL_COMMIT`,
//! `OSSVIZIER_WAL_LAYOUT` — see `ossvizier::testing::wal_opts_from_env`)
//! so the crash-matrix CI job reruns this whole file across
//! `{group-commit, serial} × {segmented, single-file}`.

use ossvizier::client::{TcpTransport, VizierClient};
use ossvizier::datastore::wal::{segment_files, tail_segment, total_log_bytes, WalDatastore, WalOptions};
use ossvizier::datastore::Datastore;
use ossvizier::pyvizier::{Algorithm, Measurement, MetricInformation, StudyConfig};
use ossvizier::service::{build_service, VizierServer};
use ossvizier::testing::wal_opts_from_env;
use ossvizier::wire::messages::ScaleType;
use std::sync::Arc;

/// Open with the matrix-selected options.
fn open_env(path: &std::path::Path) -> WalDatastore {
    WalDatastore::open_with_options(path, wal_opts_from_env()).unwrap()
}

/// Open with the matrix-selected options plus per-batch fsync.
fn open_env_sync(path: &std::path::Path) -> WalDatastore {
    WalDatastore::open_with_options(path, WalOptions { sync: true, ..wal_opts_from_env() }).unwrap()
}

fn config() -> StudyConfig {
    let mut c = StudyConfig::new("ft");
    c.search_space.add_float("x", 0.0, 1.0, ScaleType::Linear);
    c.add_metric(MetricInformation::minimize("v"));
    c.algorithm = Algorithm::RandomSearch;
    c.seed = 11;
    c
}

fn tmp(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!(
        "ossvizier-ft-{name}-{}-{}",
        std::process::id(),
        ossvizier::util::id::next_uid()
    ));
    std::fs::create_dir_all(&d).unwrap();
    d.join("store.wal")
}

#[test]
fn server_crash_preserves_all_study_state() {
    let wal_path = tmp("server-crash");
    let addr;
    // Phase 1: create study, run some trials, leave one ACTIVE, then kill
    // the server without any shutdown handshake.
    {
        let ds: Arc<dyn Datastore> = Arc::new(open_env(&wal_path));
        let service = build_service(ds, |_| {}, 4);
        let server = VizierServer::start(service, "127.0.0.1:0").unwrap();
        addr = server.local_addr().to_string();
        let mut c = VizierClient::load_or_create_study(
            Box::new(TcpTransport::connect(&addr).unwrap()),
            "ft",
            &config(),
            "w0",
        )
        .unwrap();
        for _ in 0..5 {
            let t = c.get_suggestions(1).unwrap().remove(0);
            c.complete_trial(t.id, Some(&Measurement::new(1).with_metric("v", 0.3)))
                .unwrap();
        }
        let dangling = c.get_suggestions(1).unwrap().remove(0);
        c.add_measurement(dangling.id, &Measurement::new(1).with_metric("v", 0.9))
            .unwrap();
        server.shutdown(); // hard stop; WAL is the only survivor
    }

    // Phase 2: new server process on the same WAL and port.
    let ds: Arc<dyn Datastore> = Arc::new(open_env(&wal_path));
    let service = build_service(ds, |_| {}, 4);
    service.resume_pending_operations().unwrap();
    let server = VizierServer::start(service, &addr).unwrap();
    let mut c = VizierClient::load_or_create_study(
        Box::new(TcpTransport::connect(&addr).unwrap()),
        "ft",
        &config(),
        "w0",
    )
    .unwrap();
    let trials = c.list_trials().unwrap();
    assert_eq!(trials.len(), 6, "all trials survived the crash");
    assert_eq!(trials.iter().filter(|t| t.is_completed()).count(), 5);
    // The dangling ACTIVE trial (with its measurement) is re-served to w0.
    let resumed = c.get_suggestions(1).unwrap().remove(0);
    assert_eq!(resumed.id, 6);
    assert_eq!(resumed.measurements.len(), 1, "intermediate measurement survived");
    c.complete_trial(resumed.id, None).unwrap();
    server.shutdown();
}

#[test]
fn interrupted_suggest_operation_is_resumed_after_restart() {
    // Persist an operation as if the server died between accepting the
    // RPC and running the policy; a restarted server must complete it.
    let wal_path = tmp("op-resume");
    let study_name;
    {
        let ds = open_env(&wal_path);
        let study = ds
            .create_study(ossvizier::wire::messages::StudyProto {
                display_name: "ft".into(),
                spec: ossvizier::pyvizier::converters::study_config_to_proto(&config()),
                ..Default::default()
            })
            .unwrap();
        study_name = study.name.clone();
        ds.create_operation(ossvizier::wire::messages::OperationProto {
            kind: ossvizier::wire::messages::OperationKind::SuggestTrials,
            study_name: study.name,
            client_id: "w9".into(),
            count: 3,
            done: false,
            ..Default::default()
        })
        .unwrap();
    } // crash before any policy work happened

    let ds: Arc<dyn Datastore> = Arc::new(open_env(&wal_path));
    let service = build_service(Arc::clone(&ds), |_| {}, 2);
    assert_eq!(service.resume_pending_operations().unwrap(), 1);
    // Wait for the worker to finish the resumed operation.
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    loop {
        let op = ds.get_operation("operations/1").unwrap();
        if op.done {
            assert!(op.error.is_empty(), "{}", op.error);
            assert_eq!(op.trials.len(), 3, "resumed op produced the suggestions");
            assert!(op.trials.iter().all(|t| t.client_id == "w9"));
            break;
        }
        assert!(std::time::Instant::now() < deadline, "operation never completed");
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    assert_eq!(ds.trial_count(&study_name).unwrap(), 3);
    service.shutdown();
}

#[test]
fn client_restart_same_id_gets_same_trial_other_id_does_not() {
    let ds: Arc<dyn Datastore> = Arc::new(open_env(&tmp("client")));
    let service = build_service(ds, |_| {}, 4);
    let server = VizierServer::start(service, "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();

    let mut a = VizierClient::load_or_create_study(
        Box::new(TcpTransport::connect(&addr).unwrap()),
        "ft",
        &config(),
        "alpha",
    )
    .unwrap();
    let t1 = a.get_suggestions(1).unwrap().remove(0);
    drop(a); // client crashes mid-evaluation

    // Same client_id -> same trial (paper §5).
    let mut a2 = VizierClient::load_or_create_study(
        Box::new(TcpTransport::connect(&addr).unwrap()),
        "ft",
        &config(),
        "alpha",
    )
    .unwrap();
    let t2 = a2.get_suggestions(1).unwrap().remove(0);
    assert_eq!(t1.id, t2.id);
    assert_eq!(t1.parameters, t2.parameters);

    // Different client_id -> different trial.
    let mut b = VizierClient::load_or_create_study(
        Box::new(TcpTransport::connect(&addr).unwrap()),
        "ft",
        &config(),
        "beta",
    )
    .unwrap();
    let t3 = b.get_suggestions(1).unwrap().remove(0);
    assert_ne!(t3.id, t1.id);

    // Shared client_id across two live binaries (paper §5: "multiple
    // binaries can share the same client_id and collaborate").
    let mut a3 = VizierClient::load_or_create_study(
        Box::new(TcpTransport::connect(&addr).unwrap()),
        "ft",
        &config(),
        "alpha",
    )
    .unwrap();
    let t4 = a3.get_suggestions(1).unwrap().remove(0);
    assert_eq!(t4.id, t1.id, "collaborators see the same assigned trial");
    server.shutdown();
}

#[test]
fn crash_mid_group_commit_keeps_acknowledged_mutations_only() {
    // C-FT-GC: parallel clients write through the group-commit WAL; the
    // process "crashes" leaving a torn record mid-batch. Recovery must
    // keep every acknowledged mutation and reject the torn one (§3.2:
    // acknowledged state is exactly what survives).
    let wal_path = tmp("group-crash");
    let study_name;
    let acked: usize;
    {
        let ds: Arc<dyn Datastore> =
            Arc::new(open_env_sync(&wal_path));
        let service = build_service(Arc::clone(&ds), |_| {}, 4);
        let server = VizierServer::start(service, "127.0.0.1:0").unwrap();
        let addr = server.local_addr().to_string();
        // 4 parallel clients, each completing 5 trials: all of these are
        // acknowledged (complete_trial returned), so all must survive.
        let handles: Vec<_> = (0..4)
            .map(|w| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let mut c = VizierClient::load_or_create_study(
                        Box::new(TcpTransport::connect(&addr).unwrap()),
                        "gc-crash",
                        &config(),
                        &format!("w{w}"),
                    )
                    .unwrap();
                    for _ in 0..5 {
                        let t = c.get_suggestions(1).unwrap().remove(0);
                        c.complete_trial(
                            t.id,
                            Some(&Measurement::new(1).with_metric("v", 0.5)),
                        )
                        .unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        study_name = ds.lookup_study("gc-crash").unwrap().name;
        acked = ds.trial_count(&study_name).unwrap();
        assert_eq!(acked, 20);
        server.shutdown();
    }

    // Simulate the crash tearing the in-flight (never acknowledged)
    // record: append half of a valid record to the log tail (the active
    // segment, in the segmented layout — the one place torn records are
    // legal).
    let acked_len = total_log_bytes(&wal_path);
    {
        use std::io::Write;
        // A complete record, encoded the same way the WAL does it: reuse
        // the datastore itself to produce one in a scratch log.
        let scratch = tmp("group-crash-scratch");
        {
            let ds = WalDatastore::open(&scratch).unwrap();
            ds.create_study(ossvizier::wire::messages::StudyProto {
                display_name: "torn".into(),
                ..Default::default()
            })
            .unwrap();
        }
        let full = std::fs::read(&scratch).unwrap();
        let tail = tail_segment(&wal_path).expect("log has a tail segment");
        let mut f = std::fs::OpenOptions::new().append(true).open(&tail).unwrap();
        f.write_all(&full[..full.len() / 2]).unwrap();
        f.sync_all().unwrap();
    }
    assert!(total_log_bytes(&wal_path) > acked_len);

    // Recovery: every acknowledged mutation is back, the torn record and
    // its phantom study are not, and the log is truncated to the
    // acknowledged prefix.
    let ds = open_env(&wal_path);
    assert_eq!(ds.trial_count(&study_name).unwrap(), acked);
    assert!(
        ds.list_trials(&study_name)
            .unwrap()
            .iter()
            .all(|t| t.final_measurement.is_some()),
        "acknowledged completions survived"
    );
    assert!(ds.lookup_study("torn").is_err(), "torn record rejected");
    assert_eq!(total_log_bytes(&wal_path), acked_len);
}

#[test]
fn wal_and_memory_datastores_agree_through_the_service() {
    // Differential test: the same client workload against both datastore
    // backends must produce identical trial tables.
    let run = |ds: Arc<dyn Datastore>| -> Vec<(u64, String)> {
        let service = build_service(ds, |_| {}, 2);
        let mut c = VizierClient::load_or_create_study(
            Box::new(ossvizier::client::LocalTransport::new(service)),
            "diff",
            &config(),
            "w",
        )
        .unwrap();
        for i in 0..10 {
            let t = c.get_suggestions(1).unwrap().remove(0);
            if i % 4 == 3 {
                c.report_infeasible(t.id, "bad").unwrap();
            } else {
                c.complete_trial(t.id, Some(&Measurement::new(1).with_metric("v", i as f64)))
                    .unwrap();
            }
        }
        c.list_trials()
            .unwrap()
            .into_iter()
            .map(|t| (t.id, format!("{:?}|{:?}", t.state, t.infeasibility_reason)))
            .collect()
    };
    let mem = run(Arc::new(ossvizier::datastore::memory::InMemoryDatastore::new()));
    let wal = run(Arc::new(open_env(&tmp("diff"))));
    assert_eq!(mem, wal);
}

#[test]
fn segmented_server_crash_recovers_across_rotated_segments() {
    // C-FT-SEG: a real service workload big enough to rotate the active
    // segment several times, killed without a shutdown handshake;
    // recovery replays the segments in order. Forces the segmented
    // layout (tiny segments) while inheriting the matrix commit mode.
    let wal_path = tmp("seg-rotate");
    let opts = WalOptions { segment_bytes: Some(2048), ..wal_opts_from_env() };
    let addr;
    {
        let ds: Arc<dyn Datastore> =
            Arc::new(WalDatastore::open_with_options(&wal_path, opts).unwrap());
        let service = build_service(ds, |_| {}, 4);
        let server = VizierServer::start(service, "127.0.0.1:0").unwrap();
        addr = server.local_addr().to_string();
        let mut c = VizierClient::load_or_create_study(
            Box::new(TcpTransport::connect(&addr).unwrap()),
            "ft",
            &config(),
            "w0",
        )
        .unwrap();
        for i in 0..30 {
            let t = c.get_suggestions(1).unwrap().remove(0);
            c.complete_trial(t.id, Some(&Measurement::new(1).with_metric("v", i as f64)))
                .unwrap();
        }
        server.shutdown(); // hard stop
    }
    assert!(
        segment_files(&wal_path).len() > 1,
        "workload must span several segments: {:?}",
        segment_files(&wal_path)
    );
    let ds: Arc<dyn Datastore> =
        Arc::new(WalDatastore::open_with_options(&wal_path, opts).unwrap());
    let service = build_service(Arc::clone(&ds), |_| {}, 4);
    service.resume_pending_operations().unwrap();
    let study = ds.lookup_study("ft").unwrap();
    let trials = ds.list_trials(&study.name).unwrap();
    assert_eq!(trials.len(), 30, "all trials recovered from the segment chain");
    assert!(trials.iter().all(|t| t.final_measurement.is_some()));
    service.shutdown();
}

#[test]
fn crash_at_every_compaction_stage_recovers_cleanly() {
    // The compactor can die (a) before publishing the base snapshot and
    // (b) after publishing but before deleting superseded segments.
    // Both directory states must recover to the exact pre-crash state.
    let wal_path = tmp("mid-compact");
    let opts = WalOptions { segment_bytes: Some(1024), ..wal_opts_from_env() };
    {
        let ds = WalDatastore::open_with_options(&wal_path, opts).unwrap();
        let s = ds.create_study(ossvizier::wire::messages::StudyProto {
            display_name: "mc".into(),
            ..Default::default()
        })
        .unwrap();
        for _ in 0..80 {
            ds.create_trial(&s.name, ossvizier::wire::messages::TrialProto::default())
                .unwrap();
        }
    }
    // (a) Crash before publish: an unpublished tmp snapshot is left
    // behind. Recovery ignores and deletes it.
    std::fs::write(wal_path.join("wal.000042.base.tmp"), b"half a snapshot").unwrap();
    {
        let ds = WalDatastore::open_with_options(&wal_path, opts).unwrap();
        assert_eq!(ds.trial_count("studies/1").unwrap(), 80);
    }
    assert!(
        !wal_path.join("wal.000042.base.tmp").exists(),
        "stale tmp snapshot cleaned up at open"
    );

    // (b) Crash after publish, before deletes: compact for real, then
    // resurrect copies of the superseded segments as if the unlinks
    // never happened. Replay must start at the base and ignore them.
    let superseded: Vec<(std::path::PathBuf, Vec<u8>)> = segment_files(&wal_path)
        .into_iter()
        .map(|p| {
            let bytes = std::fs::read(&p).unwrap();
            (p, bytes)
        })
        .collect();
    {
        let ds = WalDatastore::open_with_options(&wal_path, opts).unwrap();
        ds.compact().unwrap();
        for _ in 0..5 {
            ds.create_trial("studies/1", ossvizier::wire::messages::TrialProto::default())
                .unwrap();
        }
    }
    for (p, bytes) in &superseded {
        std::fs::write(p, bytes).unwrap();
    }
    {
        let ds = WalDatastore::open_with_options(&wal_path, opts).unwrap();
        assert_eq!(
            ds.trial_count("studies/1").unwrap(),
            85,
            "base + tail replay, resurrected segments ignored"
        );
        // Trial ids keep advancing past everything ever written.
        assert_eq!(
            ds.create_trial("studies/1", ossvizier::wire::messages::TrialProto::default())
                .unwrap()
                .id,
            86
        );
        let files = segment_files(&wal_path);
        assert!(
            files[0].extension().is_some_and(|e| e == "base"),
            "replay order starts at the published base: {files:?}"
        );
    }
    for (p, _) in &superseded {
        assert!(!p.exists(), "superseded segment {} cleaned up at open", p.display());
    }
}
