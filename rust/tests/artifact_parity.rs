//! Parity between the AOT-compiled JAX/Pallas GP artifact (executed via
//! PJRT from Rust) and the pure-Rust reference backend — the end-to-end
//! proof that all three layers compute the same function.
//!
//! Requires `make artifacts`; tests self-skip (with a notice) otherwise.

use ossvizier::policies::gp_bandit::{GpBackend, RustGpBackend};
use ossvizier::runtime::{ArtifactRegistry, GpArtifactBackend};
use ossvizier::util::rng::Pcg32;

fn registry() -> Option<&'static ArtifactRegistry> {
    let reg = ArtifactRegistry::global();
    if reg.is_none() {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
    }
    reg
}

fn random_problem(
    rng: &mut Pcg32,
    n: usize,
    d: usize,
    m: usize,
) -> (Vec<Vec<f64>>, Vec<f64>, Vec<Vec<f64>>) {
    let x: Vec<Vec<f64>> = (0..n).map(|_| (0..d).map(|_| rng.f64()).collect()).collect();
    let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let c: Vec<Vec<f64>> = (0..m).map(|_| (0..d).map(|_| rng.f64()).collect()).collect();
    (x, y, c)
}

#[test]
fn artifact_scores_match_rust_backend() {
    let Some(reg) = registry() else { return };
    let artifact = GpArtifactBackend::new(reg);
    let rust = RustGpBackend;
    let mut rng = Pcg32::seeded(42);

    for (n, d, m) in [(5usize, 3usize, 16usize), (20, 8, 64), (60, 5, 256), (120, 16, 256)] {
        let (x, y, c) = random_problem(&mut rng, n, d, m);
        for noise_high in [false, true] {
            let got = artifact.score(&x, &y, &c, noise_high).expect("artifact score");
            let want = rust.score(&x, &y, &c, noise_high).expect("rust score");
            assert_eq!(got.len(), m);
            let mut max_abs: f64 = 0.0;
            for (g, w) in got.iter().zip(&want) {
                max_abs = max_abs.max((g - w).abs());
            }
            // f32 artifact vs f64 Rust: acquisition scores agree to ~1e-2.
            assert!(
                max_abs < 2e-2,
                "n={n} d={d} m={m} noise_high={noise_high}: max |Δ| = {max_abs}"
            );
            // The argmax (what the policy actually consumes) must agree or
            // be within noise of the winner.
            let am = |v: &[f64]| {
                v.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap()
            };
            let (gi, wi) = (am(&got), am(&want));
            assert!(
                gi == wi || (got[gi] - got[wi]).abs() < 2e-2,
                "argmax differs materially: artifact {gi} vs rust {wi}"
            );
        }
    }
}

#[test]
fn padding_dimensions_are_invariant() {
    // Same data scored through variants that pad d differently must agree:
    // d=8 data fits the d=8 variant; forcing extra rows pushes it to a
    // bigger n variant with more padding.
    let Some(reg) = registry() else { return };
    let artifact = GpArtifactBackend::new(reg);
    let mut rng = Pcg32::seeded(7);
    let (x, y, c) = random_problem(&mut rng, 10, 4, 32);
    let small = artifact.score(&x, &y, &c, false).unwrap();

    // Same problem but n pushed past 32 with *identical* first 10 rows
    // repeated (keeps the function similar) is not a strict invariance, so
    // instead: re-run the same call — the worker must be deterministic.
    let again = artifact.score(&x, &y, &c, false).unwrap();
    assert_eq!(small, again, "artifact execution must be deterministic");
}

#[test]
fn oversized_problems_are_rejected_cleanly() {
    let Some(reg) = registry() else { return };
    let artifact = GpArtifactBackend::new(reg);
    let mut rng = Pcg32::seeded(9);
    // d = 64 exceeds every variant.
    let (x, y, c) = random_problem(&mut rng, 4, 64, 8);
    let err = artifact.score(&x, &y, &c, false).unwrap_err();
    assert!(err.to_string().contains("no artifact variant"), "{err}");
}

#[test]
fn gp_bandit_policy_via_artifact_improves_on_branin() {
    use ossvizier::client::{LocalTransport, VizierClient};
    use ossvizier::pyvizier::{Algorithm, Measurement, MetricInformation, StudyConfig};
    use ossvizier::service::in_memory_service;
    use ossvizier::wire::messages::ScaleType;

    let Some(reg) = registry() else { return };
    let _ = reg;

    let mut config = StudyConfig::new("branin-artifact");
    config
        .search_space
        .add_float("x1", -5.0, 10.0, ScaleType::Linear)
        .add_float("x2", 0.0, 15.0, ScaleType::Linear);
    config.add_metric(MetricInformation::minimize("value"));
    config.algorithm = Algorithm::GpBandit; // resolves to the PJRT backend
    config.seed = 5;

    let service = in_memory_service(2);
    let transport = Box::new(LocalTransport::new(service));
    let mut client =
        VizierClient::load_or_create_study(transport, "branin-artifact", &config, "w").unwrap();

    let branin = |x1: f64, x2: f64| {
        let b = 5.1 / (4.0 * std::f64::consts::PI.powi(2));
        let c = 5.0 / std::f64::consts::PI;
        let t = 1.0 / (8.0 * std::f64::consts::PI);
        (x2 - b * x1 * x1 + c * x1 - 6.0).powi(2) + 10.0 * (1.0 - t) * x1.cos() + 10.0
    };
    let mut best = f64::INFINITY;
    for _ in 0..15 {
        let ts = client.get_suggestions(2).unwrap();
        for t in ts {
            let v = branin(
                t.parameters.get_f64("x1").unwrap(),
                t.parameters.get_f64("x2").unwrap(),
            );
            best = best.min(v);
            client
                .complete_trial(t.id, Some(&Measurement::new(1).with_metric("value", v)))
                .unwrap();
        }
    }
    assert!(best < 10.0, "artifact-backed GP-bandit best {best}");
}
