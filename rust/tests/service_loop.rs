//! End-to-end integration: the full tuning loop of paper §3.2 / Code
//! Block 1 over the real TCP service — CreateStudy, SuggestTrials +
//! operation polling, AddMeasurement, CompleteTrial, early stopping, and
//! both Pythia deployments (in-process and separate-service).

use ossvizier::client::{LocalTransport, TcpTransport, VizierClient};
use ossvizier::pythia::runner::default_registry;
use ossvizier::pyvizier::{
    Algorithm, Measurement, MetricInformation, ObservationNoise, StudyConfig,
};
use ossvizier::service::remote_pythia::{PythiaServer, RemotePythia};
use ossvizier::service::{in_memory_service, VizierServer, VizierService};
use ossvizier::wire::messages::{ScaleType, StoppingConfig, StoppingKind};
use std::sync::Arc;

fn branin_config(algorithm: Algorithm) -> StudyConfig {
    let mut c = StudyConfig::new("branin");
    c.search_space
        .add_float("x1", -5.0, 10.0, ScaleType::Linear)
        .add_float("x2", 0.0, 15.0, ScaleType::Linear);
    c.add_metric(MetricInformation::minimize("value"));
    c.algorithm = algorithm;
    c.observation_noise = ObservationNoise::Low;
    c.seed = 17;
    c
}

fn branin(x1: f64, x2: f64) -> f64 {
    let a = 1.0;
    let b = 5.1 / (4.0 * std::f64::consts::PI.powi(2));
    let c = 5.0 / std::f64::consts::PI;
    let r = 6.0;
    let s = 10.0;
    let t = 1.0 / (8.0 * std::f64::consts::PI);
    a * (x2 - b * x1 * x1 + c * x1 - r).powi(2) + s * (1.0 - t) * x1.cos() + s
}

fn run_tuning_loop(client: &mut VizierClient, budget: usize) -> f64 {
    let mut best = f64::INFINITY;
    let mut done = 0;
    while done < budget {
        let suggestions = client.get_suggestions(2).expect("suggestions");
        assert!(!suggestions.is_empty());
        for trial in suggestions {
            let x1 = trial.parameters.get_f64("x1").unwrap();
            let x2 = trial.parameters.get_f64("x2").unwrap();
            let y = branin(x1, x2);
            best = best.min(y);
            client
                .complete_trial(trial.id, Some(&Measurement::new(1).with_metric("value", y)))
                .expect("complete");
            done += 1;
        }
    }
    best
}

#[test]
fn tcp_end_to_end_random_search() {
    let service = in_memory_service(4);
    let server = VizierServer::start(service, "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();

    let transport = Box::new(TcpTransport::connect(&addr).unwrap());
    let config = branin_config(Algorithm::RandomSearch);
    let mut client =
        VizierClient::load_or_create_study(transport, "branin", &config, "worker-0").unwrap();

    let best = run_tuning_loop(&mut client, 30);
    // Branin's global minimum is ~0.398; 30 random samples reliably get
    // under 20.
    assert!(best < 20.0, "best {best}");

    // Study state is queryable.
    let trials = client.list_trials().unwrap();
    assert_eq!(trials.len(), 30);
    assert!(trials.iter().all(|t| t.is_completed()));
    let optimal = client.list_optimal_trials().unwrap();
    assert_eq!(optimal.len(), 1);
    assert_eq!(optimal[0].final_metric("value").unwrap(), best);
    server.shutdown();
}

#[test]
fn local_transport_gp_bandit_improves() {
    let service = in_memory_service(2);
    let transport = Box::new(LocalTransport::new(service));
    let config = branin_config(Algorithm::GpBandit);
    let mut client =
        VizierClient::load_or_create_study(transport, "branin", &config, "w").unwrap();
    let best = run_tuning_loop(&mut client, 40);
    assert!(best < 10.0, "gp-bandit best {best}");
}

#[test]
fn multiple_parallel_clients_share_a_study() {
    let service = in_memory_service(8);
    let server = VizierServer::start(service, "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();
    let config = branin_config(Algorithm::RandomSearch);

    let handles: Vec<_> = (0..4)
        .map(|i| {
            let addr = addr.clone();
            let config = config.clone();
            std::thread::spawn(move || {
                let transport = Box::new(TcpTransport::connect(&addr).unwrap());
                let mut client = VizierClient::load_or_create_study(
                    transport,
                    "branin",
                    &config,
                    &format!("worker-{i}"),
                )
                .unwrap();
                run_tuning_loop(&mut client, 10);
                client.study_name.clone()
            })
        })
        .collect();
    let names: Vec<String> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    // All four replicas worked on the SAME study (first created, rest loaded).
    assert!(names.windows(2).all(|w| w[0] == w[1]), "names {names:?}");

    let transport = Box::new(TcpTransport::connect(&addr).unwrap());
    let mut client = VizierClient::for_study(transport, &names[0], "observer");
    assert_eq!(client.list_trials().unwrap().len(), 40);
    server.shutdown();
}

#[test]
fn separate_pythia_service_figure2_topology() {
    // API server with a remote-Pythia endpoint; Pythia server reads the
    // datastore back through the API server (Figure 2).
    let ds: Arc<dyn ossvizier::datastore::Datastore> =
        Arc::new(ossvizier::datastore::memory::InMemoryDatastore::new());

    // Start the API server first on an ephemeral port with a placeholder
    // remote endpoint address we fill in below (two-phase bind).
    let api_placeholder = VizierServer::start(
        VizierService::new(Arc::clone(&ds), Arc::new(RemotePythia::new("127.0.0.1:1")), 4),
        "127.0.0.1:0",
    )
    .unwrap();
    let api_addr = api_placeholder.local_addr().to_string();

    let pythia = PythiaServer::start(default_registry(), &api_addr, "127.0.0.1:0").unwrap();
    let pythia_addr = pythia.local_addr().to_string();

    // Restart the API service pointing at the live Pythia address.
    api_placeholder.shutdown();
    let service = VizierService::new(Arc::clone(&ds), Arc::new(RemotePythia::new(&pythia_addr)), 4);
    let api = VizierServer::start(service, &api_addr).unwrap();

    let transport = Box::new(TcpTransport::connect(&api_addr).unwrap());
    let config = branin_config(Algorithm::RegularizedEvolution);
    let mut client =
        VizierClient::load_or_create_study(transport, "branin-remote", &config, "w0").unwrap();
    let best = run_tuning_loop(&mut client, 20);
    assert!(best.is_finite());
    assert_eq!(client.list_trials().unwrap().len(), 20);

    // Designer state was persisted through the remote supporter.
    let stored = client.get_study_config().unwrap();
    assert!(
        stored
            .metadata
            .get_str("designer.regularized_evolution", "population")
            .is_some(),
        "designer state stored via remote pythia"
    );

    api.shutdown();
    pythia.shutdown();
}

#[test]
fn early_stopping_rpc_flow() {
    let service = in_memory_service(4);
    let transport = Box::new(LocalTransport::new(service));
    let mut config = branin_config(Algorithm::RandomSearch);
    config.metrics[0] = MetricInformation::maximize("acc");
    config.stopping = StoppingConfig {
        kind: StoppingKind::Median,
        min_trials: 3,
        confidence: 1.0,
    };
    let mut client =
        VizierClient::load_or_create_study(transport, "curves", &config, "w").unwrap();

    // Complete 4 good trials with full curves.
    for _ in 0..4 {
        let t = &client.get_suggestions(1).unwrap()[0];
        for step in 1..=10 {
            client
                .add_measurement(
                    t.id,
                    &Measurement::new(step).with_metric("acc", 0.8 * (step as f64 / 10.0)),
                )
                .unwrap();
        }
        client.complete_trial(t.id, None).unwrap(); // promotes last measurement
    }

    // A clearly bad trial: intermediate values far below the pool.
    let bad = &client.get_suggestions(1).unwrap()[0];
    for step in 1..=5 {
        client
            .add_measurement(bad.id, &Measurement::new(step).with_metric("acc", 0.01))
            .unwrap();
    }
    assert!(client.should_trial_stop(bad.id).unwrap(), "bad trial must stop");

    // A good trial is not stopped.
    let good = &client.get_suggestions(1).unwrap()[0];
    for step in 1..=5 {
        client
            .add_measurement(good.id, &Measurement::new(step).with_metric("acc", 0.9))
            .unwrap();
    }
    assert!(!client.should_trial_stop(good.id).unwrap());
}

#[test]
fn infeasible_trials_are_recorded_not_retried() {
    let service = in_memory_service(2);
    let transport = Box::new(LocalTransport::new(service));
    let config = branin_config(Algorithm::RandomSearch);
    let mut client = VizierClient::load_or_create_study(transport, "inf", &config, "w").unwrap();
    let t = &client.get_suggestions(1).unwrap()[0];
    client.report_infeasible(t.id, "nan loss").unwrap();
    let trials = client.list_trials().unwrap();
    assert_eq!(trials.len(), 1);
    assert_eq!(trials[0].infeasibility_reason.as_deref(), Some("nan loss"));
    // The next suggestion is a NEW trial (infeasible one is done).
    let t2 = &client.get_suggestions(1).unwrap()[0];
    assert_ne!(t2.id, t.id);
}
