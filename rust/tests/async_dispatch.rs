//! The operation-driven async core over real TCP: `WaitOperation` wakes
//! parked clients the instant a policy result lands (no busy-poll), a
//! slow reader's half-written response parks instead of pinning the
//! pool's worker (procfs thread-budget assertion), crash-resume
//! completes a parked wait, and the per-connection idle timeout evicts
//! dead fleets.

use ossvizier::client::transport::{call, TcpTransport, Transport};
use ossvizier::client::VizierClient;
use ossvizier::datastore::memory::InMemoryDatastore;
use ossvizier::datastore::Datastore;
use ossvizier::pythia::policy::{Policy, PolicyError, SuggestDecision, SuggestRequest};
use ossvizier::pythia::supporter::PolicySupporter;
use ossvizier::pyvizier::{converters, Algorithm, MetricInformation, StudyConfig, TrialSuggestion};
use ossvizier::service::{build_service, ServerOptions, VizierServer, VizierService};
use ossvizier::testing::poller_from_env;
use ossvizier::testing::procfs::threads_with_prefix;
use ossvizier::wire::framing::{read_response, write_request, Method};
use ossvizier::wire::messages::{
    CreateStudyRequest, EmptyResponse, ListTrialsRequest, ListTrialsResponse, MetadataItem,
    OperationKind, OperationProto, OperationResponse, ScaleType, StudyProto, TrialProto,
    WaitOperationRequest,
};
use std::io::{BufReader, Read};
use std::net::TcpStream;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock};
use std::time::{Duration, Instant};

/// Tests in this file count live threads by name via /proc, so they must
/// not overlap with each other's servers: serialize the whole file.
fn serial() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|poisoned| poisoned.into_inner())
}

fn test_config(algorithm: Algorithm) -> StudyConfig {
    let mut c = StudyConfig::new("async");
    c.search_space.add_float("x", 0.0, 1.0, ScaleType::Linear);
    c.add_metric(MetricInformation::maximize("score"));
    c.algorithm = algorithm;
    c.seed = 11;
    c
}

fn wait_until(what: &str, deadline: Duration, mut cond: impl FnMut() -> bool) {
    let by = Instant::now() + deadline;
    while !cond() {
        assert!(Instant::now() < by, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

// ---------------------------------------------------------------------------
// A policy whose first invocation blocks on a gate, so tests can pile up
// operations deterministically while the single policy worker is busy.
// ---------------------------------------------------------------------------

#[derive(Default)]
struct Gate {
    open: Mutex<bool>,
    cv: Condvar,
}

impl Gate {
    fn release(&self) {
        *self.open.lock().unwrap() = true;
        self.cv.notify_all();
    }

    fn wait(&self) {
        let mut open = self.open.lock().unwrap();
        while !*open {
            open = self.cv.wait(open).unwrap();
        }
    }
}

struct GatedPolicy {
    gate: Arc<Gate>,
    invocations: Arc<AtomicUsize>,
}

impl Policy for GatedPolicy {
    fn suggest(
        &mut self,
        req: &SuggestRequest,
        _s: &dyn PolicySupporter,
    ) -> Result<SuggestDecision, PolicyError> {
        if self.invocations.fetch_add(1, Ordering::SeqCst) == 0 {
            self.gate.wait(); // only the first invocation blocks
        }
        Ok(SuggestDecision::from_flat(
            req,
            vec![TrialSuggestion::default(); req.total_count()],
        ))
    }
}

fn gated_service(
    ds: Arc<dyn Datastore>,
    policy_workers: usize,
) -> (Arc<VizierService>, Arc<Gate>, Arc<AtomicUsize>) {
    let gate = Arc::new(Gate::default());
    let invocations = Arc::new(AtomicUsize::new(0));
    let (g, inv) = (Arc::clone(&gate), Arc::clone(&invocations));
    let service = build_service(
        ds,
        move |reg| {
            reg.register(
                "GATED",
                Arc::new(move |_| {
                    Box::new(GatedPolicy {
                        gate: Arc::clone(&g),
                        invocations: Arc::clone(&inv),
                    })
                }),
            );
        },
        policy_workers,
    );
    (service, gate, invocations)
}

fn ping(stream: &mut TcpStream) {
    write_request(stream, Method::Ping, &EmptyResponse::default()).unwrap();
    let mut r = BufReader::new(stream.try_clone().unwrap());
    let _: EmptyResponse = read_response(&mut r).unwrap();
}

/// Many clients suggest against one gated study and park in
/// `WaitOperation`; the policy completion wakes all of them in one
/// round-trip each, with zero `GetOperation` polling and the front-end
/// at its thread budget throughout.
#[test]
fn wait_operation_wakes_parked_clients_over_tcp() {
    let _serial = serial();
    let ds: Arc<dyn Datastore> = Arc::new(InMemoryDatastore::new());
    let (service, gate, invocations) = gated_service(Arc::clone(&ds), 1);
    let fe_workers = 2;
    let server = VizierServer::start_with(
        Arc::clone(&service),
        "127.0.0.1:0",
        ServerOptions { workers: fe_workers, poller: poller_from_env(), ..Default::default() },
    )
    .unwrap();
    let addr = server.local_addr().to_string();
    let config = test_config(Algorithm::Custom("GATED".into()));
    let study = service
        .create_study(CreateStudyRequest {
            study: StudyProto {
                display_name: "async".into(),
                spec: converters::study_config_to_proto(&config),
                ..Default::default()
            },
        })
        .unwrap()
        .study;

    // Client 0's policy run occupies the single policy worker (blocked
    // on the gate); make sure it actually started before piling on, so
    // clients 1..4 coalesce behind it instead of racing it.
    let n = 5usize;
    let clients: Vec<_> = (0..n)
        .map(|i| {
            let addr = addr.clone();
            let study_name = study.name.clone();
            let handle = std::thread::Builder::new()
                .name(format!("waiter-{i}"))
                .spawn(move || {
                    let mut client = VizierClient::for_study(
                        Box::new(TcpTransport::connect(&addr).unwrap()),
                        &study_name,
                        &format!("client-{i}"),
                    );
                    client.get_suggestions(1).unwrap()
                })
                .unwrap();
            if i == 0 {
                let inv = Arc::clone(&invocations);
                wait_until("first policy run to start", Duration::from_secs(20), || {
                    inv.load(Ordering::SeqCst) > 0
                });
            }
            handle
        })
        .collect();

    // All five clients end up waiting server-side: five pending
    // operations and — depending on the negotiated wire — five parked
    // long-poll responses (v1) or five watch streams (v2). No extra
    // threads either way.
    let fe = Arc::clone(server.frontend_metrics());
    let svc_metrics = Arc::clone(&service.metrics);
    wait_until("all clients parked", Duration::from_secs(20), || {
        fe.parked_responses() + svc_metrics.watch_streams() == n as u64
    });
    assert_eq!(service.metrics.in_flight_policy_jobs(), n as u64);
    assert_eq!(ds.pending_operations().unwrap().len(), n);
    if let Some(threads) = threads_with_prefix("vizier-fe") {
        assert!(
            threads <= fe_workers + 2,
            "{n} parked waiters must not cost threads: {threads} > {}",
            fe_workers + 2
        );
    }

    gate.release();
    for c in clients {
        let trials = c.join().unwrap();
        assert_eq!(trials.len(), 1);
    }

    // The new client path never touched GetOperation — completion was
    // pushed, not polled (on both wires).
    assert_eq!(service.metrics.histogram("GetOperation").count(), 0);
    assert_eq!(service.metrics.histogram("WaitOperation").count(), n as u64);
    assert_eq!(service.metrics.wait_wakeup.count(), n as u64);
    assert_eq!(service.metrics.in_flight_policy_jobs(), 0);
    assert_eq!(service.metrics.watch_streams(), 0, "watch streams must drain");
    // Coalescing still held: the four queued ops shared one policy run.
    assert_eq!(invocations.load(Ordering::SeqCst), 2);
    server.shutdown();
}

/// A client that requests a huge listing and then stops reading parks
/// its half-written response in the event loop; the pool's single
/// worker keeps serving everyone else, and the response completes once
/// the client drains it.
#[test]
fn slow_reader_response_parks_and_frees_worker() {
    let _serial = serial();
    let ds = Arc::new(InMemoryDatastore::new());
    let service = ossvizier::service::build_service(
        Arc::clone(&ds) as Arc<dyn Datastore>,
        |_| {},
        1,
    );
    let fe_workers = 1;
    let server = VizierServer::start_with(
        Arc::clone(&service),
        "127.0.0.1:0",
        ServerOptions { workers: fe_workers, poller: poller_from_env(), ..Default::default() },
    )
    .unwrap();
    let addr = server.local_addr();

    // ~14 MiB of trials (under the 16 MiB frame cap): far beyond what
    // the kernel will buffer for one connection, so the response write
    // must park.
    let study = ds
        .create_study(StudyProto { display_name: "fat".into(), ..Default::default() })
        .unwrap();
    let trials = 64usize;
    for _ in 0..trials {
        ds.create_trial(
            &study.name,
            TrialProto {
                metadata: vec![MetadataItem {
                    namespace: "blob".into(),
                    key: "payload".into(),
                    value: vec![0xAB; 220_000],
                }],
                ..Default::default()
            },
        )
        .unwrap();
    }

    // Two slow readers request the listing and read nothing.
    let mut slow: Vec<TcpStream> = (0..2)
        .map(|_| {
            let mut s = TcpStream::connect(addr).unwrap();
            s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
            write_request(
                &mut s,
                Method::ListTrials,
                &ListTrialsRequest { study_name: study.name.clone(), ..Default::default() },
            )
            .unwrap();
            s
        })
        .collect();

    let fe = Arc::clone(server.frontend_metrics());
    wait_until("a response to park", Duration::from_secs(10), || fe.parked_responses() >= 1);
    if let Some(threads) = threads_with_prefix("vizier-fe") {
        assert!(
            threads <= fe_workers + 2,
            "slow readers must not grow the pool: {threads} > {}",
            fe_workers + 2
        );
    }

    // The one and only worker is free: another client gets served while
    // both big responses are stalled.
    let start = Instant::now();
    let mut other = TcpStream::connect(addr).unwrap();
    other.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    ping(&mut other);
    assert!(
        start.elapsed() < Duration::from_secs(5),
        "stalled response writes pinned the single pool worker"
    );

    // Drain both responses: parked writes resume and complete.
    for s in slow.iter_mut() {
        let mut r = BufReader::new(s.try_clone().unwrap());
        let resp: ListTrialsResponse = read_response(&mut r).unwrap();
        assert_eq!(resp.trials.len(), trials);
    }
    wait_until("parked gauge to drain", Duration::from_secs(10), || fe.parked_responses() == 0);
    server.shutdown();
}

/// Crash-resume wakes a parked wait: an operation interrupted by a
/// "crash" (written pending to the datastore, no live runner) completes
/// after `resume_pending_operations`, and the client parked on it is
/// woken by the same watcher path as live traffic.
#[test]
fn crash_resume_completes_a_parked_wait() {
    let _serial = serial();
    let ds: Arc<dyn Datastore> = Arc::new(InMemoryDatastore::new());
    let config = test_config(Algorithm::RandomSearch);
    let study = ds
        .create_study(StudyProto {
            display_name: "resume".into(),
            spec: converters::study_config_to_proto(&config),
            ..Default::default()
        })
        .unwrap();
    // The crash artifact: a persisted, pending suggest operation with
    // no server ever having picked it up.
    let op = ds
        .create_operation(OperationProto {
            kind: OperationKind::SuggestTrials,
            study_name: study.name.clone(),
            client_id: "w0".into(),
            count: 1,
            ..Default::default()
        })
        .unwrap();

    // "Restart": a fresh service over the surviving datastore.
    let service = build_service(Arc::clone(&ds), |_| {}, 2);
    let server = VizierServer::start(Arc::clone(&service), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();

    let op_name = op.name.clone();
    let waiter = std::thread::spawn(move || {
        let mut t = TcpTransport::connect(&addr).unwrap();
        let resp: OperationResponse = call(
            &mut t as &mut dyn Transport,
            Method::WaitOperation,
            &WaitOperationRequest { name: op_name, timeout_ms: 30_000 },
        )
        .unwrap();
        resp.operation
    });

    let fe = Arc::clone(server.frontend_metrics());
    let svc_metrics = Arc::clone(&service.metrics);
    wait_until("the wait to park", Duration::from_secs(10), || {
        fe.parked_responses() + svc_metrics.watch_streams() == 1
    });
    // Still pending: nothing has run it.
    assert!(!ds.get_operation(&op.name).unwrap().done);

    let resumed = service.resume_pending_operations().unwrap();
    assert_eq!(resumed, 1);

    let done = waiter.join().unwrap();
    assert!(done.done, "resume must complete the parked operation");
    assert!(done.error.is_empty(), "unexpected error: {}", done.error);
    assert_eq!(done.trials.len(), 1);
    assert_eq!(service.metrics.wait_wakeup.count(), 1);
    server.shutdown();
}

/// `--idle-timeout-secs`: connections that stop talking are evicted
/// (gauge drops, counter increments, socket closed) while fresh
/// connections keep working.
#[test]
fn idle_timeout_evicts_idle_connections() {
    let _serial = serial();
    let service = ossvizier::service::in_memory_service(1);
    let server = VizierServer::start_with(
        service,
        "127.0.0.1:0",
        ServerOptions {
            workers: 1,
            idle_timeout: Some(Duration::from_millis(300)),
            poller: poller_from_env(),
            ..Default::default()
        },
    )
    .unwrap();
    let addr = server.local_addr();
    let mut fleet: Vec<TcpStream> = (0..3)
        .map(|_| {
            let mut s = TcpStream::connect(addr).unwrap();
            s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
            ping(&mut s);
            s
        })
        .collect();
    assert_eq!(server.frontend_metrics().active_connections(), 3);

    let fe = Arc::clone(server.frontend_metrics());
    wait_until("idle fleet eviction", Duration::from_secs(10), || {
        fe.active_connections() == 0
    });
    assert!(fe.idle_evictions() >= 3);
    // The evicted sockets observe EOF.
    let mut buf = [0u8; 1];
    assert_eq!(fleet[0].read(&mut buf).unwrap_or(0), 0);

    // New connections are unaffected (activity resets the clock on each
    // request).
    let mut fresh = TcpStream::connect(addr).unwrap();
    fresh.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    ping(&mut fresh);
    drop(fleet);
    server.shutdown();
}

/// `--max-connections`: excess connections are refused (closed without
/// a response) and counted, while admitted clients keep working.
#[test]
fn max_connections_refuses_excess_clients() {
    let _serial = serial();
    let service = ossvizier::service::in_memory_service(1);
    let server = VizierServer::start_with(
        service,
        "127.0.0.1:0",
        ServerOptions {
            workers: 1,
            max_connections: 2,
            poller: poller_from_env(),
            ..Default::default()
        },
    )
    .unwrap();
    let addr = server.local_addr();
    let mut a = TcpStream::connect(addr).unwrap();
    let mut b = TcpStream::connect(addr).unwrap();
    a.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    b.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    ping(&mut a);
    ping(&mut b);

    let mut refused = TcpStream::connect(addr).unwrap();
    refused.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut buf = [0u8; 1];
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        match refused.read(&mut buf) {
            Ok(0) => break, // closed without serving
            Ok(_) => panic!("refused connection got data"),
            Err(_) => assert!(Instant::now() < deadline, "refused conn never closed"),
        }
    }
    assert_eq!(server.frontend_metrics().connections_refused(), 1);
    ping(&mut a);
    server.shutdown();
}
