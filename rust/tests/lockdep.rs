//! End-to-end tests for the lockdep-instrumented sync layer
//! (`ossvizier::util::sync`).
//!
//! Two angles: a deliberate cross-thread A→B / B→A inversion must be
//! detected from the observed-order graph alone (no unlucky
//! interleaving needed, and neither thread ever actually deadlocks),
//! and a full server smoke — WAL datastore, coalescing, front-end,
//! operation waiters — must run clean with the detector force-enabled,
//! pinning the production lock hierarchy end to end.

use ossvizier::client::{TcpTransport, VizierClient};
use ossvizier::datastore::wal::WalDatastore;
use ossvizier::datastore::Datastore;
use ossvizier::pyvizier::{Algorithm, Measurement, MetricInformation, StudyConfig};
use ossvizier::service::{build_service, VizierServer};
use ossvizier::util::sync::{lockdep_enabled, LockClass, Mutex};
use ossvizier::wire::messages::ScaleType;
use std::sync::Arc;

/// Force the detector on regardless of build profile. Cached on first
/// lock acquisition, so every test sets it before touching any lock;
/// all tests in this binary agree on the value.
fn enable_lockdep() {
    std::env::set_var("OSSVIZIER_LOCKDEP", "1");
    assert!(lockdep_enabled(), "OSSVIZIER_LOCKDEP=1 must enable the detector");
}

// Ranks far above the production table (and the sync.rs unit-test band)
// so these classes never collide with real locks in this process.
static ORD_A: LockClass = LockClass::new("test.lockdep.a", 20_000);
static ORD_B: LockClass = LockClass::new("test.lockdep.b", 20_010);

/// The tentpole scenario: thread 1 nests A→B (legal, records the edge),
/// thread 2 nests B→A *after thread 1 is gone* — no deadlock can occur,
/// but the inversion closes a cycle in the order graph and must panic
/// naming both classes.
#[test]
fn cross_thread_inversion_panics_with_both_class_names() {
    enable_lockdep();
    let a = Arc::new(Mutex::new(&ORD_A, ()));
    let b = Arc::new(Mutex::new(&ORD_B, ()));

    {
        let (a, b) = (Arc::clone(&a), Arc::clone(&b));
        std::thread::spawn(move || {
            let _ga = a.lock();
            let _gb = b.lock(); // in rank order: clean, records a -> b
        })
        .join()
        .expect("in-order thread must not panic");
    }

    let err = std::thread::spawn(move || {
        let _gb = b.lock();
        let _ga = a.lock(); // closes the cycle: must panic
    })
    .join()
    .expect_err("B -> A after an observed A -> B must panic under lockdep");

    let msg = err
        .downcast_ref::<String>()
        .cloned()
        .unwrap_or_default();
    assert!(msg.contains("lockdep"), "panic is attributed to the detector: {msg}");
    assert!(msg.contains("test.lockdep.a"), "panic names the acquired class: {msg}");
    assert!(msg.contains("test.lockdep.b"), "panic names the held class: {msg}");
}

fn config(name: &str) -> StudyConfig {
    let mut c = StudyConfig::new(name);
    c.search_space.add_float("x", 0.0, 1.0, ScaleType::Linear);
    c.add_metric(MetricInformation::maximize("score"));
    c.algorithm = Algorithm::RandomSearch;
    c.seed = 7;
    c
}

fn tmp_wal() -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!(
        "ossvizier-lockdep-{}-{}",
        std::process::id(),
        ossvizier::util::id::next_uid()
    ));
    std::fs::create_dir_all(&d).unwrap();
    d.join("store.wal")
}

/// Whole-stack smoke with the detector on: concurrent clients drive
/// suggest → complete through the front-end, the coalescing layer, the
/// operation waiters, and the WAL commit path, then a compaction runs.
/// Any lock acquired out of hierarchy anywhere on those paths panics
/// the serving thread and fails the client call.
#[test]
fn full_server_smoke_is_clean_under_lockdep() {
    enable_lockdep();
    let ds = Arc::new(WalDatastore::open(tmp_wal()).unwrap());
    let service = build_service(Arc::clone(&ds) as Arc<dyn Datastore>, |_| {}, 4);
    let server = VizierServer::start(service, "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();

    let rounds = 5;
    let workers = 4;
    let handles: Vec<_> = (0..workers)
        .map(|w| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut client = VizierClient::load_or_create_study(
                    Box::new(TcpTransport::connect(&addr).unwrap()),
                    "lockdep-smoke",
                    &config("lockdep-smoke"),
                    &format!("w{w}"),
                )
                .unwrap();
                for i in 0..rounds {
                    let t = client.get_suggestions(1).unwrap().remove(0);
                    client
                        .complete_trial(
                            t.id,
                            Some(&Measurement::new(1).with_metric("score", i as f64)),
                        )
                        .unwrap();
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("client worker survived — no lockdep panic on the serve path");
    }

    // Compaction holds the gate/log/compactor locks in their declared
    // order while commits may still be arriving.
    ds.compact().unwrap();

    let study = ds.lookup_study("lockdep-smoke").unwrap();
    assert_eq!(ds.trial_count(&study.name).unwrap(), workers * rounds);
    server.shutdown();
}
