//! Cross-module property tests: wire-format robustness against arbitrary
//! bytes, service-level consistency under randomized operation sequences,
//! and policy feasibility invariants across all registered algorithms.

use ossvizier::client::{LocalTransport, VizierClient};
use ossvizier::pyvizier::{Algorithm, Measurement, MetricInformation, StudyConfig};
use ossvizier::service::in_memory_service;
use ossvizier::testing::prop::check;
use ossvizier::wire::codec::decode;
use ossvizier::wire::messages::*;
use ossvizier::wire::framing::Method;

#[test]
fn decoding_arbitrary_bytes_never_panics() {
    check("wire decode is total", 2000, |g| {
        let bytes = g.vec(64, |g| g.u64_below(256) as u8);
        // Every message type must either decode or error — never panic.
        let _ = decode::<TrialProto>(&bytes);
        let _ = decode::<StudyProto>(&bytes);
        let _ = decode::<StudySpecProto>(&bytes);
        let _ = decode::<OperationProto>(&bytes);
        let _ = decode::<ParameterSpecProto>(&bytes);
        let _ = decode::<SuggestTrialsRequest>(&bytes);
        let _ = decode::<ossvizier::wire::messages::Measurement>(&bytes);
    });
}

#[test]
fn mutated_valid_messages_never_panic() {
    // Flip bytes inside a valid encoding: decoder must stay total.
    check("wire decode survives corruption", 500, |g| {
        let trial = TrialProto {
            id: 7,
            state: TrialState::Completed,
            parameters: vec![TrialParameter {
                parameter_id: "x".into(),
                value: ParamValue::F64(1.5),
            }],
            final_measurement: Some(ossvizier::wire::messages::Measurement {
                step_count: 3,
                elapsed_secs: 1.0,
                metrics: vec![Metric { metric_id: "m".into(), value: 0.5 }],
            }),
            ..Default::default()
        };
        let mut bytes = ossvizier::wire::codec::encode(&trial);
        let flips = g.usize_range(1, 4);
        for _ in 0..flips {
            let i = g.usize_range(0, bytes.len() - 1);
            let b = g.u64_below(256) as u8;
            bytes[i] = b;
        }
        let _ = decode::<TrialProto>(&bytes);
    });
}

#[test]
fn service_rejects_malformed_frames_without_dying() {
    // Raw garbage payloads against every method id: the service must answer
    // with an error frame (or a valid response for empty-payload methods),
    // and keep serving afterwards.
    let service = in_memory_service(2);
    for method_id in 1..=17u8 {
        let method = Method::from_u8(method_id).unwrap();
        let garbage = vec![0xFFu8, 0x07, 0x99, 0x01];
        let resp = ossvizier::service::server::dispatch_buf(&service, method, &garbage);
        assert!(!resp.is_empty(), "method {method:?} must produce a response frame");
    }
    // Still alive:
    let mut c = VizierClient::for_study(Box::new(LocalTransport::new(service)), "none", "x");
    c.ping().unwrap();
}

fn base_config(algorithm: Algorithm) -> StudyConfig {
    let mut c = StudyConfig::new("prop");
    c.search_space
        .add_float("lr", 1e-4, 1e-1, ossvizier::wire::messages::ScaleType::Log)
        .add_int("layers", 1, 5)
        .add_discrete("batch", vec![16.0, 32.0, 64.0])
        .add_categorical("opt", vec!["sgd", "adam"]);
    c.add_metric(MetricInformation::maximize("score"));
    c.algorithm = algorithm;
    c.seed = 1234;
    c
}

#[test]
fn every_algorithm_produces_feasible_suggestions_through_the_service() {
    for alg in [
        Algorithm::RandomSearch,
        Algorithm::GridSearch,
        Algorithm::QuasiRandomSearch,
        Algorithm::HillClimb,
        Algorithm::RegularizedEvolution,
        Algorithm::HarmonySearch,
        Algorithm::Firefly,
        Algorithm::Custom("GP_BANDIT_RUST".into()),
    ] {
        let config = base_config(alg.clone());
        let service = in_memory_service(2);
        let mut client = VizierClient::load_or_create_study(
            Box::new(LocalTransport::new(service)),
            "prop",
            &config,
            "w",
        )
        .unwrap();
        for round in 0..6 {
            let suggestions = client.get_suggestions(3).unwrap();
            assert_eq!(suggestions.len(), 3, "{alg:?} round {round}");
            for t in suggestions {
                config
                    .search_space
                    .validate(&t.parameters)
                    .unwrap_or_else(|e| panic!("{alg:?} produced infeasible params: {e}"));
                let score = t.parameters.get_f64("lr").unwrap().log10();
                client
                    .complete_trial(t.id, Some(&Measurement::new(1).with_metric("score", score)))
                    .unwrap();
            }
        }
    }
}

#[test]
fn randomized_client_op_sequences_keep_state_consistent() {
    check("randomized op sequences", 30, |g| {
        let config = base_config(Algorithm::RandomSearch);
        let service = in_memory_service(2);
        let mut client = VizierClient::load_or_create_study(
            Box::new(LocalTransport::new(service)),
            "prop",
            &config,
            "w",
        )
        .unwrap();
        let mut active: Vec<u64> = Vec::new();
        let mut completed = 0usize;
        let mut infeasible = 0usize;
        for _ in 0..g.usize_range(5, 25) {
            match g.u64_below(4) {
                0 => {
                    let got = client.get_suggestions(g.usize_range(1, 3)).unwrap();
                    for t in got {
                        if !active.contains(&t.id) {
                            active.push(t.id);
                        }
                    }
                }
                1 if !active.is_empty() => {
                    let id = active.remove(g.usize_range(0, active.len() - 1));
                    client
                        .complete_trial(id, Some(&Measurement::new(1).with_metric("score", 0.5)))
                        .unwrap();
                    completed += 1;
                }
                2 if !active.is_empty() => {
                    let id = active.remove(g.usize_range(0, active.len() - 1));
                    client.report_infeasible(id, "prop-test").unwrap();
                    infeasible += 1;
                }
                _ if !active.is_empty() => {
                    let id = *g.pick(&active);
                    client
                        .add_measurement(id, &Measurement::new(1).with_metric("score", 0.1))
                        .unwrap();
                }
                _ => {}
            }
        }
        // Datastore view must agree with the client's bookkeeping.
        let trials = client.list_trials().unwrap();
        let n_completed = trials
            .iter()
            .filter(|t| t.state == ossvizier::pyvizier::TrialState::Completed)
            .count();
        let n_infeasible = trials
            .iter()
            .filter(|t| t.state == ossvizier::pyvizier::TrialState::Infeasible)
            .count();
        assert_eq!(n_completed, completed);
        assert_eq!(n_infeasible, infeasible);
        // Completing a completed trial must fail cleanly.
        if let Some(t) = trials.iter().find(|t| t.is_completed()) {
            assert!(client
                .complete_trial(t.id, Some(&Measurement::new(1).with_metric("score", 0.0)))
                .is_err());
        }
    });
}

#[test]
fn shard_routing_is_a_stable_function_of_the_study_name() {
    use ossvizier::datastore::memory::InMemoryDatastore;
    check("same study name always maps to the same shard", 500, |g| {
        let name = g.string(48);
        let ds1 = InMemoryDatastore::new();
        let ds2 = InMemoryDatastore::new();
        // Stable within one store, across stores, and in range.
        let idx = ds1.shard_index(&name);
        assert_eq!(idx, ds1.shard_index(&name));
        assert_eq!(idx, ds2.shard_index(&name));
        assert!(idx < ds1.shard_count());
        // Shard count changes may move the study, but routing stays
        // deterministic for every count.
        for shards in [1usize, 2, 7, 16, 64] {
            let ds = InMemoryDatastore::with_shards(shards);
            assert_eq!(ds.shard_index(&name), ds.shard_index(&name));
            assert!(ds.shard_index(&name) < shards);
        }
    });
}

#[test]
fn list_studies_equals_the_union_of_per_shard_contents() {
    use ossvizier::datastore::memory::InMemoryDatastore;
    use ossvizier::datastore::Datastore;
    use ossvizier::wire::messages::StudyProto;
    check("list_studies == union of shards", 60, |g| {
        let ds = InMemoryDatastore::with_shards(g.usize_range(1, 32));
        let n = g.usize_range(0, 30);
        let mut names = Vec::new();
        for i in 0..n {
            let s = ds
                .create_study(StudyProto {
                    display_name: format!("prop-{i}"),
                    ..Default::default()
                })
                .unwrap();
            names.push(s.name);
        }
        // Random deletions keep the invariant interesting.
        let deletes = g.usize_range(0, n / 2 + 1);
        for _ in 0..deletes.min(names.len()) {
            let i = g.usize_range(0, names.len() - 1);
            let name = names.swap_remove(i);
            ds.delete_study(&name).unwrap();
        }
        // Each surviving study is resident in exactly the shard its name
        // hashes to, and nowhere else.
        for name in &names {
            let home = ds.shard_index(name);
            for idx in 0..ds.shard_count() {
                let present = ds.studies_in_shard(idx).contains(name);
                assert_eq!(present, idx == home, "{name} in shard {idx}, home {home}");
            }
        }
        // Union over shards == list_studies.
        let mut union: Vec<String> = (0..ds.shard_count())
            .flat_map(|i| ds.studies_in_shard(i))
            .collect();
        union.sort();
        let mut listed: Vec<String> =
            ds.list_studies().unwrap().into_iter().map(|s| s.name).collect();
        listed.sort();
        assert_eq!(union, listed);
    });
}

#[test]
fn segment_prefix_plus_torn_tail_replays_to_acked_prefix_per_study() {
    // The segmented-WAL recovery invariant: for ANY crash point — i.e.
    // any prefix of the segment chain (base kept if published) with the
    // new final segment torn at an arbitrary byte — replay yields, for
    // every study, a dense prefix of that study's acknowledged commits.
    // Interior trials keep their acked mutate; only the very last
    // surviving trial may have lost its (possibly unacked) mutate.
    use ossvizier::datastore::wal::{segment_files, WalDatastore, WalOptions};
    use ossvizier::datastore::Datastore;
    use ossvizier::wire::messages::{StudyProto, TrialProto};

    check("segment prefix + torn tail = per-study acked prefix", 20, |g| {
        let dir = std::env::temp_dir().join(format!(
            "ossvizier-prop-seg-{}-{}",
            std::process::id(),
            ossvizier::util::id::next_uid()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("wal");
        let opts = WalOptions {
            group_commit: g.bool(),
            segment_bytes: Some(g.usize_range(200, 2000) as u64),
            ..WalOptions::default()
        };
        let n_studies = g.usize_range(1, 4);
        // recorded[s][id-1] = the created_ms acked for that trial.
        let mut recorded: Vec<Vec<u64>> = vec![Vec::new(); n_studies];
        let mut names: Vec<String> = Vec::new();
        {
            let ds = WalDatastore::open_with_options(&path, opts).unwrap();
            for i in 0..n_studies {
                names.push(
                    ds.create_study(StudyProto {
                        display_name: format!("p{i}"),
                        ..Default::default()
                    })
                    .unwrap()
                    .name,
                );
            }
            let ops = g.usize_range(10, 80);
            for seq in 0..ops {
                let s = g.usize_range(0, n_studies - 1);
                let t = ds.create_trial(&names[s], TrialProto::default()).unwrap();
                ds.mutate_trial(&names[s], t.id, &mut |t| {
                    t.created_ms = seq as u64 + 1;
                    Ok(())
                })
                .unwrap();
                recorded[s].push(seq as u64 + 1);
                // Sometimes a compaction lands mid-history, so the crash
                // point can fall anywhere relative to a published base.
                if seq == ops / 2 && g.bool() {
                    ds.compact().unwrap();
                }
            }
        } // crash: no shutdown handshake
        let logs: Vec<_> = segment_files(&path)
            .into_iter()
            .filter(|p| p.extension().is_some_and(|e| e == "log"))
            .collect();
        let keep = g.usize_range(0, logs.len());
        for p in &logs[keep..] {
            std::fs::remove_file(p).unwrap();
        }
        if keep > 0 {
            let tail = &logs[keep - 1];
            let len = std::fs::metadata(tail).unwrap().len();
            let cut = g.u64_below(len + 1);
            std::fs::OpenOptions::new().write(true).open(tail).unwrap().set_len(cut).unwrap();
        }
        let ds = WalDatastore::open_with_options(&path, opts).unwrap();
        for (s, name) in names.iter().enumerate() {
            let trials = match ds.list_trials(name) {
                Ok(t) => t,
                // The study's own create record was cut: the k = 0 prefix.
                Err(_) => continue,
            };
            let k = trials.len();
            assert!(k <= recorded[s].len(), "{name}: phantom trials after replay");
            for (j, t) in trials.iter().enumerate() {
                assert_eq!(t.id, j as u64 + 1, "{name}: ids must form a dense prefix");
                if j + 1 < k {
                    assert_eq!(
                        t.created_ms, recorded[s][j],
                        "{name}: interior trial lost its acked mutate"
                    );
                } else {
                    assert!(
                        t.created_ms == recorded[s][j] || t.created_ms == 0,
                        "{name}: tail trial must hold the acked value or the torn default"
                    );
                }
            }
        }
        drop(ds);
        let _ = std::fs::remove_dir_all(&dir);
    });
}

#[test]
fn snapshot_under_writers_and_compaction_is_prefix_consistent() {
    // The copy-on-write read invariant: a snapshot taken at ANY moment —
    // here while 8 writers stream trials into their own studies and a
    // forced compaction cycles the WAL — observes a prefix-consistent
    // image. Concretely, per study: trial ids form a dense 1..=k prefix
    // (no holes, no phantoms), every write acknowledged *before* the
    // read began is visible (k covers the acked floor), and no trial is
    // torn (its two correlated fields, written in one record, always
    // agree). Runs under the crash-matrix env, so the CoW legs cover
    // both the snapshot path and the lock-per-read baseline.
    use ossvizier::datastore::wal::{WalDatastore, WalOptions};
    use ossvizier::datastore::Datastore;
    use ossvizier::wire::messages::{StudyProto, TrialProto};
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
    use std::sync::Arc;

    const WRITERS: usize = 8;
    check("snapshot under 8 writers + compaction = consistent prefix", 3, |g| {
        let dir = std::env::temp_dir().join(format!(
            "ossvizier-prop-snap-{}-{}",
            std::process::id(),
            ossvizier::util::id::next_uid()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let opts = WalOptions {
            segment_bytes: Some(g.usize_range(4_000, 32_000) as u64),
            ..ossvizier::testing::wal_opts_from_env()
        };
        let ds = Arc::new(WalDatastore::open_with_options(dir.join("wal"), opts).unwrap());
        let names: Arc<Vec<String>> = Arc::new(
            (0..WRITERS)
                .map(|i| {
                    ds.create_study(StudyProto {
                        display_name: format!("snap{i}"),
                        ..Default::default()
                    })
                    .unwrap()
                    .name
                })
                .collect(),
        );
        let acked: Arc<Vec<AtomicU64>> =
            Arc::new((0..WRITERS).map(|_| AtomicU64::new(0)).collect());
        let per_writer = g.usize_range(40, 120);
        let stop = Arc::new(AtomicBool::new(false));

        let reader = {
            let ds = Arc::clone(&ds);
            let names = Arc::clone(&names);
            let acked = Arc::clone(&acked);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut scans = 0u64;
                while !stop.load(Ordering::SeqCst) {
                    for s in 0..WRITERS {
                        // Everything acked before the scan starts must be
                        // visible in the image the scan walks.
                        let floor = acked[s].load(Ordering::SeqCst);
                        let trials = ds.list_trials(&names[s]).unwrap();
                        assert!(
                            trials.len() as u64 >= floor,
                            "study {s}: snapshot lost acked writes ({} < {floor})",
                            trials.len()
                        );
                        for (j, t) in trials.iter().enumerate() {
                            assert_eq!(
                                t.id,
                                j as u64 + 1,
                                "study {s}: ids must form a dense prefix"
                            );
                            // Both fields were written by one record: a
                            // disagreement would be a torn trial.
                            assert_eq!(
                                t.client_id,
                                format!("c{}", t.created_ms),
                                "study {s} trial {}: torn trial observed",
                                t.id
                            );
                        }
                        scans += 1;
                    }
                }
                scans
            })
        };
        let writers: Vec<_> = (0..WRITERS)
            .map(|s| {
                let ds = Arc::clone(&ds);
                let names = Arc::clone(&names);
                let acked = Arc::clone(&acked);
                std::thread::spawn(move || {
                    for seq in 1..=per_writer as u64 {
                        let t = ds
                            .create_trial(
                                &names[s],
                                TrialProto {
                                    created_ms: seq,
                                    client_id: format!("c{seq}"),
                                    ..Default::default()
                                },
                            )
                            .unwrap();
                        assert_eq!(t.id, seq, "per-study ids are sequential");
                        acked[s].store(seq, Ordering::SeqCst);
                    }
                })
            })
            .collect();
        // Force a full compaction mid-stream; in CoW mode its base
        // snapshot is cut from pinned images with zero shard locks.
        ds.compact().unwrap();
        for w in writers {
            w.join().unwrap();
        }
        stop.store(true, Ordering::SeqCst);
        let scans = reader.join().unwrap();
        assert!(scans > 0, "reader never completed a scan");
        for (s, name) in names.iter().enumerate() {
            let trials = ds.list_trials(name).unwrap();
            assert_eq!(trials.len(), per_writer, "study {s}: final state complete");
        }
        drop(ds);
        let _ = std::fs::remove_dir_all(&dir);
    });
}

#[test]
fn grid_search_exhausts_small_spaces_without_duplicates() {
    let mut config = StudyConfig::new("grid");
    config.search_space.add_int("a", 0, 3).add_categorical("b", vec!["x", "y"]);
    config.add_metric(MetricInformation::maximize("m"));
    config.algorithm = Algorithm::GridSearch;
    let service = in_memory_service(2);
    let mut client = VizierClient::load_or_create_study(
        Box::new(LocalTransport::new(service)),
        "grid",
        &config,
        "w",
    )
    .unwrap();
    let mut seen = std::collections::HashSet::new();
    for _ in 0..8 {
        let t = client.get_suggestions(1).unwrap().remove(0);
        seen.insert(format!("{:?}", t.parameters));
        client
            .complete_trial(t.id, Some(&Measurement::new(1).with_metric("m", 0.0)))
            .unwrap();
    }
    assert_eq!(seen.len(), 8, "8 distinct grid points over a cardinality-8 space");
}
