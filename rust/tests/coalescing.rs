//! Pythia v2 integration tests: per-study suggest-operation coalescing,
//! crash-resume without double-serving, partial-registration rollback,
//! batched early stopping end-to-end over the wire, and paginated study
//! listing through the service.

use ossvizier::client::{TcpTransport, VizierClient};
use ossvizier::datastore::memory::InMemoryDatastore;
use ossvizier::datastore::query::TrialFilter;
use ossvizier::datastore::{Datastore, DsError};
use ossvizier::pythia::policy::{
    EarlyStopDecision, EarlyStopRequest, Policy, PolicyError, SuggestDecision, SuggestRequest,
};
use ossvizier::pythia::supporter::PolicySupporter;
use ossvizier::pyvizier::{
    converters, Algorithm, Measurement, MetricInformation, StudyConfig, TrialSuggestion,
};
use ossvizier::service::{build_service, VizierServer, VizierService};
use ossvizier::wire::messages::{
    ListStudiesRequest, OperationKind, OperationProto, ScaleType, StoppingConfig, StoppingKind,
    StudyProto, TrialProto, TrialState, UnitMetadataUpdate,
};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

fn test_config(algorithm: Algorithm) -> StudyConfig {
    let mut c = StudyConfig::new("coal");
    c.search_space.add_float("x", 0.0, 1.0, ScaleType::Linear);
    c.add_metric(MetricInformation::maximize("score"));
    c.algorithm = algorithm;
    c.seed = 5;
    c
}

fn wait_done(ds: &Arc<dyn Datastore>, op_name: &str) -> OperationProto {
    let deadline = Instant::now() + Duration::from_secs(20);
    loop {
        let op = ds.get_operation(op_name).unwrap();
        if op.done {
            return op;
        }
        assert!(Instant::now() < deadline, "operation {op_name} never completed");
        std::thread::sleep(Duration::from_millis(5));
    }
}

// ---------------------------------------------------------------------------
// A policy whose first invocation blocks on a gate, so tests can pile up
// operations deterministically while the single worker is busy.
// ---------------------------------------------------------------------------

#[derive(Default)]
struct Gate {
    open: Mutex<bool>,
    cv: Condvar,
}

impl Gate {
    fn release(&self) {
        *self.open.lock().unwrap() = true;
        self.cv.notify_all();
    }

    fn wait(&self) {
        let mut open = self.open.lock().unwrap();
        while !*open {
            open = self.cv.wait(open).unwrap();
        }
    }
}

struct GatedPolicy {
    gate: Arc<Gate>,
    invocations: Arc<AtomicUsize>,
}

impl Policy for GatedPolicy {
    fn suggest(
        &mut self,
        req: &SuggestRequest,
        _s: &dyn PolicySupporter,
    ) -> Result<SuggestDecision, PolicyError> {
        if self.invocations.fetch_add(1, Ordering::SeqCst) == 0 {
            self.gate.wait(); // only the first invocation blocks
        }
        Ok(SuggestDecision::from_flat(
            req,
            vec![TrialSuggestion::default(); req.total_count()],
        ))
    }
}

fn gated_service(
    ds: Arc<dyn Datastore>,
    workers: usize,
) -> (Arc<VizierService>, Arc<Gate>, Arc<AtomicUsize>) {
    let gate = Arc::new(Gate::default());
    let invocations = Arc::new(AtomicUsize::new(0));
    let (g, inv) = (Arc::clone(&gate), Arc::clone(&invocations));
    let service = build_service(
        ds,
        move |reg| {
            reg.register(
                "GATED",
                Arc::new(move |_| {
                    Box::new(GatedPolicy {
                        gate: Arc::clone(&g),
                        invocations: Arc::clone(&inv),
                    })
                }),
            );
        },
        workers,
    );
    (service, gate, invocations)
}

#[test]
fn coalesced_suggests_share_one_policy_invocation() {
    let ds: Arc<dyn Datastore> = Arc::new(InMemoryDatastore::new());
    let (service, gate, invocations) = gated_service(Arc::clone(&ds), 1);
    let config = test_config(Algorithm::Custom("GATED".into()));
    let study = service
        .create_study(ossvizier::wire::messages::CreateStudyRequest {
            study: StudyProto {
                display_name: "coal".into(),
                spec: converters::study_config_to_proto(&config),
                ..Default::default()
            },
        })
        .unwrap()
        .study;

    // Op 0 occupies the single worker (its policy run blocks on the gate).
    let first = service
        .suggest_trials(ossvizier::wire::messages::SuggestTrialsRequest {
            study_name: study.name.clone(),
            count: 1,
            client_id: "c0".into(),
        })
        .unwrap()
        .operation;
    // Wait until the blocked policy run actually started, so ops 1..8 all
    // pile up in the study's queue behind it.
    let deadline = Instant::now() + Duration::from_secs(10);
    while invocations.load(Ordering::SeqCst) == 0 {
        assert!(Instant::now() < deadline, "first policy run never started");
        std::thread::sleep(Duration::from_millis(2));
    }

    // N-1 threads enqueue suggest ops concurrently while the worker is
    // stuck; they all pile up in the study's queue.
    let n = 8usize;
    let mut expected_total = 1; // op 0 asked for 1
    let handles: Vec<_> = (1..n)
        .map(|i| {
            let service = Arc::clone(&service);
            let study_name = study.name.clone();
            std::thread::spawn(move || {
                let count = i as u64; // varied counts exercise partitioning
                let op = service
                    .suggest_trials(ossvizier::wire::messages::SuggestTrialsRequest {
                        study_name,
                        count,
                        client_id: format!("c{i}"),
                    })
                    .unwrap()
                    .operation;
                (op, format!("c{i}"), count as usize)
            })
        })
        .collect();
    let ops: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    for (_, _, count) in &ops {
        expected_total += count;
    }
    gate.release();

    let first_done = wait_done(&ds, &first.name);
    assert_eq!(first_done.trials.len(), 1);
    let mut total = first_done.trials.len();
    let mut all_ids: Vec<u64> = first_done.trials.iter().map(|t| t.id).collect();
    for (op, client, count) in &ops {
        let done = wait_done(&ds, &op.name);
        assert!(done.error.is_empty(), "{}", done.error);
        // (a) each op got exactly what it asked for,
        // (b) every trial is assigned to the op's own client.
        assert_eq!(done.trials.len(), *count, "op for {client}");
        assert!(done.trials.iter().all(|t| t.client_id == *client));
        total += done.trials.len();
        all_ids.extend(done.trials.iter().map(|t| t.id));
    }
    // Total suggestions == sum of requested counts; no trial served twice.
    assert_eq!(total, expected_total);
    all_ids.sort_unstable();
    all_ids.dedup();
    assert_eq!(all_ids.len(), expected_total, "no trial shared between ops");

    // (c) strictly fewer policy invocations than operations: ops 1..8
    // coalesced into one batch behind the gated first run.
    let runs = invocations.load(Ordering::SeqCst);
    assert!(runs < n, "expected < {n} policy invocations, got {runs}");
    assert_eq!(service.metrics.policy_runs(), runs as u64);
    assert_eq!(service.metrics.suggest_ops_served(), n as u64);
    service.shutdown();
}

#[test]
fn resume_recoalesces_without_double_serving() {
    // Persist a study and 5 interrupted suggest ops as if the server died
    // before any policy work, then restart and resume.
    let ds: Arc<dyn Datastore> = Arc::new(InMemoryDatastore::new());
    let config = test_config(Algorithm::RandomSearch);
    let study = ds
        .create_study(StudyProto {
            display_name: "resume".into(),
            spec: converters::study_config_to_proto(&config),
            ..Default::default()
        })
        .unwrap();
    let mut op_names = Vec::new();
    let mut expected_total = 0usize;
    for i in 0..5u64 {
        let count = i + 1;
        expected_total += count as usize;
        let op = ds
            .create_operation(OperationProto {
                kind: OperationKind::SuggestTrials,
                study_name: study.name.clone(),
                client_id: format!("w{i}"),
                count,
                done: false,
                ..Default::default()
            })
            .unwrap();
        op_names.push(op.name);
    }

    let service = build_service(Arc::clone(&ds), |_| {}, 2);
    // A second resume racing the first must not double-serve anything:
    // queued/claimed bookkeeping dedupes by operation name.
    assert_eq!(service.resume_pending_operations().unwrap(), 5);
    let _ = service.resume_pending_operations();

    let mut total = 0usize;
    for name in &op_names {
        let op = wait_done(&ds, name);
        assert!(op.error.is_empty(), "{}", op.error);
        total += op.trials.len();
    }
    assert_eq!(total, expected_total, "each op served exactly once");
    assert_eq!(
        ds.trial_count(&study.name).unwrap(),
        expected_total,
        "no duplicate registrations from the duplicate resume"
    );
    // All 5 ops were pending at resume time, so they coalesced into fewer
    // policy invocations than operations.
    assert!(service.metrics.policy_runs() < 5);
    service.shutdown();
}

// ---------------------------------------------------------------------------
// Partial-registration rollback (satellite regression test)
// ---------------------------------------------------------------------------

/// Delegating datastore whose `create_trial` fails on the Nth call.
struct FailingDatastore {
    inner: InMemoryDatastore,
    creates: AtomicUsize,
    fail_on: usize,
}

impl Datastore for FailingDatastore {
    fn create_study(&self, study: StudyProto) -> Result<StudyProto, DsError> {
        self.inner.create_study(study)
    }
    fn get_study(&self, name: &str) -> Result<StudyProto, DsError> {
        self.inner.get_study(name)
    }
    fn lookup_study(&self, display_name: &str) -> Result<StudyProto, DsError> {
        self.inner.lookup_study(display_name)
    }
    fn list_studies(&self) -> Result<Vec<StudyProto>, DsError> {
        self.inner.list_studies()
    }
    fn update_study(&self, study: StudyProto) -> Result<(), DsError> {
        self.inner.update_study(study)
    }
    fn delete_study(&self, name: &str) -> Result<(), DsError> {
        self.inner.delete_study(name)
    }
    fn create_trial(&self, study: &str, trial: TrialProto) -> Result<TrialProto, DsError> {
        if self.creates.fetch_add(1, Ordering::SeqCst) + 1 == self.fail_on {
            return Err(DsError::Storage("injected create_trial failure".into()));
        }
        self.inner.create_trial(study, trial)
    }
    fn get_trial(&self, study: &str, id: u64) -> Result<TrialProto, DsError> {
        self.inner.get_trial(study, id)
    }
    fn list_trials(&self, study: &str) -> Result<Vec<TrialProto>, DsError> {
        self.inner.list_trials(study)
    }
    fn update_trial(&self, study: &str, trial: TrialProto) -> Result<(), DsError> {
        self.inner.update_trial(study, trial)
    }
    fn delete_trial(&self, study: &str, id: u64) -> Result<(), DsError> {
        self.inner.delete_trial(study, id)
    }
    fn mutate_trial(
        &self,
        study: &str,
        id: u64,
        f: &mut dyn FnMut(&mut TrialProto) -> Result<(), DsError>,
    ) -> Result<TrialProto, DsError> {
        self.inner.mutate_trial(study, id, f)
    }
    fn create_operation(&self, op: OperationProto) -> Result<OperationProto, DsError> {
        self.inner.create_operation(op)
    }
    fn get_operation(&self, name: &str) -> Result<OperationProto, DsError> {
        self.inner.get_operation(name)
    }
    fn update_operation(&self, op: OperationProto) -> Result<(), DsError> {
        self.inner.update_operation(op)
    }
    fn pending_operations(&self) -> Result<Vec<OperationProto>, DsError> {
        self.inner.pending_operations()
    }
    fn update_metadata(
        &self,
        study: &str,
        updates: &[UnitMetadataUpdate],
    ) -> Result<(), DsError> {
        self.inner.update_metadata(study, updates)
    }
    fn trial_count(&self, study: &str) -> Result<usize, DsError> {
        self.inner.trial_count(study)
    }
}

#[test]
fn partial_registration_rolls_back_to_infeasible() {
    // create_trial fails on the 3rd call: two trials of a count=4 op get
    // registered, then the op must roll them back instead of leaving
    // orphaned ACTIVE trials assigned to the client.
    let ds: Arc<dyn Datastore> = Arc::new(FailingDatastore {
        inner: InMemoryDatastore::new(),
        creates: AtomicUsize::new(0),
        fail_on: 3,
    });
    let service = build_service(Arc::clone(&ds), |_| {}, 1);
    let config = test_config(Algorithm::RandomSearch);
    let study = service
        .create_study(ossvizier::wire::messages::CreateStudyRequest {
            study: StudyProto {
                display_name: "rollback".into(),
                spec: converters::study_config_to_proto(&config),
                ..Default::default()
            },
        })
        .unwrap()
        .study;

    let op = service
        .suggest_trials(ossvizier::wire::messages::SuggestTrialsRequest {
            study_name: study.name.clone(),
            count: 4,
            client_id: "w0".into(),
        })
        .unwrap()
        .operation;
    let done = wait_done(&ds, &op.name);

    // Error contract: the op reports the failure and hands out no trials.
    assert!(done.error.contains("failed to register trial"), "{}", done.error);
    assert!(done.trials.is_empty(), "failed op must not expose trials");
    // The two already-registered trials were rolled back to INFEASIBLE.
    let trials = ds.list_trials(&study.name).unwrap();
    assert_eq!(trials.len(), 2);
    for t in &trials {
        assert_eq!(t.state, TrialState::Infeasible);
        assert!(t.infeasibility_reason.contains("rolled back"), "{}", t.infeasibility_reason);
    }
    // Nothing ACTIVE is left assigned to the client, so its next suggest
    // is not fed orphans via the client-fault-tolerance fast path.
    assert!(ds
        .query_trials(&study.name, &TrialFilter::active().for_client("w0"))
        .unwrap()
        .is_empty());
    service.shutdown();
}

// ---------------------------------------------------------------------------
// Batched early stopping end-to-end: client -> TCP -> service -> policy ->
// client (acceptance criterion).
// ---------------------------------------------------------------------------

/// Early-stopping test policy: stops every odd trial id.
struct StopOddPolicy;

impl Policy for StopOddPolicy {
    fn suggest(
        &mut self,
        req: &SuggestRequest,
        _s: &dyn PolicySupporter,
    ) -> Result<SuggestDecision, PolicyError> {
        Ok(SuggestDecision::from_flat(
            req,
            vec![TrialSuggestion::default(); req.total_count()],
        ))
    }
    fn early_stop(
        &mut self,
        req: &EarlyStopRequest,
        _s: &dyn PolicySupporter,
    ) -> Result<Vec<EarlyStopDecision>, PolicyError> {
        Ok(req
            .trial_ids
            .iter()
            .map(|&id| {
                if id % 2 == 1 {
                    EarlyStopDecision::stop(id, "odd trial")
                } else {
                    EarlyStopDecision::keep(id)
                }
            })
            .collect())
    }
}

#[test]
fn batched_early_stopping_over_the_wire() {
    let ds: Arc<dyn Datastore> = Arc::new(InMemoryDatastore::new());
    let service = build_service(
        Arc::clone(&ds),
        |reg| reg.register("STOP_ODD", Arc::new(|_| Box::new(StopOddPolicy))),
        4,
    );
    let server = VizierServer::start(service, "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();

    let config = test_config(Algorithm::Custom("STOP_ODD".into()));
    let transport = Box::new(TcpTransport::connect(&addr).unwrap());
    let mut client =
        VizierClient::load_or_create_study(transport, "es-batch", &config, "w").unwrap();

    // Four running trials (ids 1..=4).
    let trials = client.get_suggestions(4).unwrap();
    assert_eq!(trials.len(), 4);
    let ids: Vec<u64> = trials.iter().map(|t| t.id).collect();

    // Explicit batch: per-trial decisions come back in one operation.
    let decisions = client.check_early_stopping(&ids).unwrap();
    assert_eq!(decisions.len(), 4);
    for d in &decisions {
        assert_eq!(d.should_stop, d.trial_id % 2 == 1, "trial {}", d.trial_id);
        if d.should_stop {
            assert_eq!(d.reason, "odd trial");
        }
    }
    // Stopped trials moved to STOPPING server-side.
    for id in &ids {
        let t = ds.get_trial(&client.study_name, *id).unwrap();
        if id % 2 == 1 {
            assert_eq!(t.state, TrialState::Stopping);
        } else {
            assert_eq!(t.state, TrialState::Active);
        }
    }

    // Empty list = every trial still ACTIVE (the two even ones).
    let all = client.check_early_stopping(&[]).unwrap();
    let mut judged: Vec<u64> = all.iter().map(|d| d.trial_id).collect();
    judged.sort_unstable();
    let mut active: Vec<u64> = ids.iter().copied().filter(|id| id % 2 == 0).collect();
    active.sort_unstable();
    assert_eq!(judged, active);

    // The single-trial convenience still works on top of the batch API.
    assert!(!client.should_trial_stop(active[0]).unwrap());
    server.shutdown();
}

#[test]
fn builtin_stopping_rule_judges_batches() {
    // Median rule through the batched surface (no custom policy): bad
    // curve stops, good curve continues, decided in ONE operation.
    let ds: Arc<dyn Datastore> = Arc::new(InMemoryDatastore::new());
    let service = build_service(Arc::clone(&ds), |_| {}, 2);
    let server = VizierServer::start(service, "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();

    let mut config = test_config(Algorithm::RandomSearch);
    config.metrics[0] = MetricInformation::maximize("acc");
    config.stopping = StoppingConfig {
        kind: StoppingKind::Median,
        min_trials: 3,
        confidence: 1.0,
    };
    let transport = Box::new(TcpTransport::connect(&addr).unwrap());
    let mut client =
        VizierClient::load_or_create_study(transport, "es-median", &config, "w").unwrap();

    for _ in 0..4 {
        let t = &client.get_suggestions(1).unwrap()[0];
        for step in 1..=10 {
            client
                .add_measurement(
                    t.id,
                    &Measurement::new(step).with_metric("acc", 0.8 * (step as f64 / 10.0)),
                )
                .unwrap();
        }
        client.complete_trial(t.id, None).unwrap();
    }
    let bad = client.get_suggestions(1).unwrap()[0].id;
    let good = client.get_suggestions(1).unwrap()[0].id;
    for step in 1..=5 {
        client
            .add_measurement(bad, &Measurement::new(step).with_metric("acc", 0.01))
            .unwrap();
        client
            .add_measurement(good, &Measurement::new(step).with_metric("acc", 0.9))
            .unwrap();
    }
    let decisions = client.check_early_stopping(&[bad, good]).unwrap();
    assert_eq!(decisions.len(), 2);
    let verdict = |id: u64| decisions.iter().find(|d| d.trial_id == id).unwrap();
    assert!(verdict(bad).should_stop, "bad trial must stop");
    assert!(!verdict(good).should_stop, "good trial must continue");
    assert!(verdict(bad).reason.contains("median"), "{}", verdict(bad).reason);
    server.shutdown();
}

// ---------------------------------------------------------------------------
// Paginated study listing through the service (satellite).
// ---------------------------------------------------------------------------

#[test]
fn service_list_studies_paginates() {
    let ds: Arc<dyn Datastore> = Arc::new(InMemoryDatastore::new());
    let service = build_service(Arc::clone(&ds), |_| {}, 1);
    let config = test_config(Algorithm::RandomSearch);
    for i in 0..23 {
        ds.create_study(StudyProto {
            display_name: format!("pg{i}"),
            spec: converters::study_config_to_proto(&config),
            ..Default::default()
        })
        .unwrap();
    }

    // Legacy shape: no page_size -> everything, no token.
    let all = service.list_studies(ListStudiesRequest::default()).unwrap();
    assert_eq!(all.studies.len(), 23);
    assert!(all.next_page_token.is_empty());

    // Paginated walk covers every study exactly once.
    let mut seen = Vec::new();
    let mut token = String::new();
    loop {
        let resp = service
            .list_studies(ListStudiesRequest {
                page_size: 5,
                page_token: token.clone(),
            })
            .unwrap();
        assert!(resp.studies.len() <= 5);
        seen.extend(resp.studies.iter().map(|s| s.name.clone()));
        if resp.next_page_token.is_empty() {
            break;
        }
        token = resp.next_page_token;
    }
    seen.sort();
    let mut want: Vec<String> = all.studies.iter().map(|s| s.name.clone()).collect();
    want.sort();
    assert_eq!(seen, want);

    // Malformed tokens map to InvalidArgument at the API layer.
    assert!(service
        .list_studies(ListStudiesRequest {
            page_size: 5,
            page_token: "not-a-token".into(),
        })
        .is_err());
    service.shutdown();
}
