//! Cross-version wire matrix: a v1 client against a v2 server and a v2
//! client against the same server both complete the full
//! suggest/report/early-stop loop; one multiplexed connection carries
//! many concurrent in-flight RPCs; the v2 `WaitOperation` watch stream
//! observes every operation transition with zero `GetOperation` calls;
//! and CANCEL / mid-stream disconnect leave no leaked waiter, parked
//! slot, or gauge drift (asserted through `GetServiceMetrics`, the way a
//! fleet operator would see it). See `rust/docs/WIRE.md` for the
//! protocol itself.

use ossvizier::client::transport::{TcpTransport, Transport};
use ossvizier::client::VizierClient;
use ossvizier::datastore::memory::InMemoryDatastore;
use ossvizier::datastore::Datastore;
use ossvizier::pythia::policy::{Policy, PolicyError, SuggestDecision, SuggestRequest};
use ossvizier::pythia::runner::default_registry;
use ossvizier::pythia::supporter::PolicySupporter;
use ossvizier::pyvizier::{
    converters, Algorithm, Measurement, MetricInformation, StudyConfig, TrialSuggestion,
};
use ossvizier::service::remote_pythia::{PythiaServer, RemotePythia};
use ossvizier::service::{build_service, ServerOptions, VizierServer, VizierService};
use ossvizier::testing::poller_from_env;
use ossvizier::testing::procfs::threads_with_prefix;
use ossvizier::wire::codec::{decode, encode};
use ossvizier::wire::framing::{
    encode_v2_request, parse_v2, read_frame, read_response, write_v2, FrameError, FrameKind,
    Method, Status, WIRE_VERSION_MAX,
};
use ossvizier::wire::messages::{
    CreateStudyRequest, EmptyResponse, HelloProto, OperationKind, OperationProto,
    OperationResponse, ScaleType, ServiceMetricsResponse, StudyProto, WaitOperationRequest,
};
use std::io::{BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock};
use std::time::{Duration, Instant};

/// Tests here count threads via /proc and read process-global gauges, so
/// they must not overlap with each other's servers.
fn serial() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// The CI matrix leg `OSSVIZIER_WIRE=v1` pins every transport to the
/// legacy protocol; v2-specific tests detect that and degrade to a
/// no-op (the v1 coverage in this file is what that leg is for).
fn env_forced_v1() -> bool {
    std::env::var("OSSVIZIER_WIRE").map(|v| v == "v1").unwrap_or(false)
}

fn test_config(algorithm: Algorithm) -> StudyConfig {
    let mut c = StudyConfig::new("matrix");
    c.search_space.add_float("x", 0.0, 1.0, ScaleType::Linear);
    c.add_metric(MetricInformation::maximize("score"));
    c.algorithm = algorithm;
    c.seed = 23;
    c
}

fn wait_until(what: &str, deadline: Duration, mut cond: impl FnMut() -> bool) {
    let by = Instant::now() + deadline;
    while !cond() {
        assert!(Instant::now() < by, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

fn gauge(resp: &ServiceMetricsResponse, name: &str) -> u64 {
    resp.gauges.iter().find(|g| g.name == name).map_or(0, |g| g.value)
}

fn hist_count(resp: &ServiceMetricsResponse, name: &str) -> u64 {
    resp.histograms.iter().find(|h| h.name == name).map_or(0, |h| h.count)
}

// ---------------------------------------------------------------------------
// A policy whose first invocation blocks on a gate (same shape as
// tests/async_dispatch.rs), so operations stay in flight deterministically.
// ---------------------------------------------------------------------------

#[derive(Default)]
struct Gate {
    open: Mutex<bool>,
    cv: Condvar,
}

impl Gate {
    fn release(&self) {
        *self.open.lock().unwrap() = true;
        self.cv.notify_all();
    }

    fn wait(&self) {
        let mut open = self.open.lock().unwrap();
        while !*open {
            open = self.cv.wait(open).unwrap();
        }
    }
}

struct GatedPolicy {
    gate: Arc<Gate>,
    invocations: Arc<AtomicUsize>,
}

impl Policy for GatedPolicy {
    fn suggest(
        &mut self,
        req: &SuggestRequest,
        _s: &dyn PolicySupporter,
    ) -> Result<SuggestDecision, PolicyError> {
        if self.invocations.fetch_add(1, Ordering::SeqCst) == 0 {
            self.gate.wait(); // only the first invocation blocks
        }
        Ok(SuggestDecision::from_flat(
            req,
            vec![TrialSuggestion::default(); req.total_count()],
        ))
    }
}

fn gated_service(
    ds: Arc<dyn Datastore>,
    policy_workers: usize,
) -> (Arc<VizierService>, Arc<Gate>, Arc<AtomicUsize>) {
    let gate = Arc::new(Gate::default());
    let invocations = Arc::new(AtomicUsize::new(0));
    let (g, inv) = (Arc::clone(&gate), Arc::clone(&invocations));
    let service = build_service(
        ds,
        move |reg| {
            reg.register(
                "GATED",
                Arc::new(move |_| {
                    Box::new(GatedPolicy {
                        gate: Arc::clone(&g),
                        invocations: Arc::clone(&inv),
                    })
                }),
            );
        },
        policy_workers,
    );
    (service, gate, invocations)
}

fn start_server(service: Arc<VizierService>, workers: usize) -> VizierServer {
    VizierServer::start_with(
        service,
        "127.0.0.1:0",
        ServerOptions { workers, poller: poller_from_env(), ..Default::default() },
    )
    .unwrap()
}

/// One full client lifecycle — create/load study, suggest, report
/// intermediate measurements, complete, early-stop query, list — used
/// identically by both matrix legs below.
fn run_full_loop(transport: TcpTransport, study: &str) {
    let config = test_config(Algorithm::RandomSearch);
    let mut client =
        VizierClient::load_or_create_study(Box::new(transport), study, &config, "w0").unwrap();
    for _ in 0..3 {
        let trials = client.get_suggestions(2).unwrap();
        assert_eq!(trials.len(), 2);
        for t in trials {
            client
                .add_measurement(t.id, &Measurement::new(1).with_metric("score", 0.5))
                .unwrap();
            // Early-stop check rides the same loop (no stopping policy
            // configured, so the answer is "keep going").
            assert!(!client.should_trial_stop(t.id).unwrap());
            client
                .complete_trial(t.id, Some(&Measurement::new(2).with_metric("score", 0.7)))
                .unwrap();
        }
    }
    let trials = client.list_trials().unwrap();
    assert_eq!(trials.len(), 6);
    assert!(trials.iter().all(|t| t.is_completed()));
}

/// A v1-pinned client completes the whole tuning loop against a v2
/// server: the server must keep serving the legacy protocol forever.
#[test]
fn v1_client_full_loop_against_v2_server() {
    let _serial = serial();
    let server = start_server(ossvizier::service::in_memory_service(2), 2);
    let addr = server.local_addr().to_string();

    let mut t = TcpTransport::connect(&addr).unwrap();
    t.force_v1();
    assert_eq!(t.wire_version(), 1);
    run_full_loop(t, "matrix-v1");
    server.shutdown();
}

/// The default transport negotiates v2 against the same server and runs
/// the identical loop; the negotiated version is asserted so a silent
/// fallback to v1 cannot fake this test green.
#[test]
fn v2_client_full_loop_with_negotiated_mux() {
    let _serial = serial();
    let server = start_server(ossvizier::service::in_memory_service(2), 2);
    let addr = server.local_addr().to_string();

    let t = TcpTransport::connect(&addr).unwrap();
    if !env_forced_v1() {
        assert_eq!(t.wire_version(), 2, "HELLO negotiation must land on v2");
    }
    run_full_loop(t, "matrix-v2");
    server.shutdown();
}

/// Acceptance: a single multiplexed connection carries >= 8 concurrent
/// in-flight RPCs. Eight clients share one transport (`try_share`),
/// all suggest against a gated study, and all eight waits are in flight
/// on ONE socket (front-end `active_connections == 1`) before the gate
/// opens and every client completes.
#[test]
fn one_connection_carries_eight_concurrent_inflight_rpcs() {
    let _serial = serial();
    if env_forced_v1() {
        eprintln!("skipping: OSSVIZIER_WIRE=v1 pins the legacy protocol");
        return;
    }
    let ds: Arc<dyn Datastore> = Arc::new(InMemoryDatastore::new());
    let (service, gate, invocations) = gated_service(Arc::clone(&ds), 1);
    let server = start_server(Arc::clone(&service), 2);
    let addr = server.local_addr().to_string();
    let config = test_config(Algorithm::Custom("GATED".into()));
    let study = service
        .create_study(CreateStudyRequest {
            study: StudyProto {
                display_name: "matrix".into(),
                spec: converters::study_config_to_proto(&config),
                ..Default::default()
            },
        })
        .unwrap()
        .study;

    let base = TcpTransport::connect(&addr).unwrap();
    assert_eq!(base.wire_version(), 2);

    let n = 8usize;
    let spawn_worker = |t: TcpTransport, i: usize| {
        let study = study.name.clone();
        std::thread::spawn(move || {
            let mut client = VizierClient::for_study(Box::new(t), &study, &format!("w{i}"));
            client.get_suggestions(1).unwrap().len()
        })
    };

    // Worker 0's policy run occupies the single policy worker (blocked
    // on the gate); make sure it started before piling on, so workers
    // 1..7 coalesce behind it instead of racing it.
    let mut handles = vec![spawn_worker(base.try_share().unwrap(), 0)];
    wait_until("the gated policy run to start", Duration::from_secs(10), || {
        invocations.load(Ordering::SeqCst) >= 1
    });
    for i in 1..n {
        handles.push(spawn_worker(base.try_share().unwrap(), i));
    }

    // All eight operations are in flight concurrently: eight watch
    // streams registered, all multiplexed over the one TCP connection.
    let fe = Arc::clone(server.frontend_metrics());
    let svc_metrics = Arc::clone(&service.metrics);
    wait_until("eight in-flight waits", Duration::from_secs(20), || {
        svc_metrics.watch_streams() == n as u64
    });
    assert_eq!(fe.active_connections(), 1, "all RPCs must share one socket");

    gate.release();
    for h in handles {
        assert_eq!(h.join().unwrap(), 1);
    }
    assert_eq!(service.metrics.watch_streams(), 0, "watch streams must drain");
    assert_eq!(service.metrics.histogram("GetOperation").count(), 0);
    server.shutdown();
}

/// Acceptance: the v2 watch stream observes every operation transition
/// — the registration snapshot (pending) and the completion (done) each
/// arrive as a `STREAM_ITEM` — with zero `GetOperation` calls.
#[test]
fn watch_stream_observes_every_transition_without_polling() {
    let _serial = serial();
    if env_forced_v1() {
        eprintln!("skipping: OSSVIZIER_WIRE=v1 pins the legacy protocol");
        return;
    }
    let ds: Arc<dyn Datastore> = Arc::new(InMemoryDatastore::new());
    let config = test_config(Algorithm::RandomSearch);
    let study = ds
        .create_study(StudyProto {
            display_name: "watch".into(),
            spec: converters::study_config_to_proto(&config),
            ..Default::default()
        })
        .unwrap();
    // A persisted pending operation with no live runner (the
    // crash-resume artifact): its only transition is resume -> done.
    let op = ds
        .create_operation(OperationProto {
            kind: OperationKind::SuggestTrials,
            study_name: study.name.clone(),
            client_id: "w0".into(),
            count: 1,
            ..Default::default()
        })
        .unwrap();

    let service = build_service(Arc::clone(&ds), |_| {}, 2);
    let server = start_server(Arc::clone(&service), 2);
    let addr = server.local_addr().to_string();

    let mut t = TcpTransport::connect(&addr).unwrap();
    assert_eq!(t.wire_version(), 2);
    let req = WaitOperationRequest { name: op.name.clone(), timeout_ms: 0 };
    let mut stream = t
        .call_stream(Method::WaitOperation, &encode(&req))
        .unwrap()
        .expect("v2 transport must open a watch stream");

    // First item: the registration snapshot of the still-pending op.
    let first = stream.next(Some(Duration::from_secs(10))).unwrap().expect("snapshot item");
    let snap: OperationResponse = decode(&first).unwrap();
    assert!(!snap.operation.done, "registration snapshot must be the pending state");

    wait_until("the watcher to register", Duration::from_secs(10), || {
        service.metrics.watch_streams() == 1
    });
    assert_eq!(service.resume_pending_operations().unwrap(), 1);

    // Every further transition is pushed; the stream ends after `done`.
    let mut items = Vec::new();
    while let Some(body) = stream.next(Some(Duration::from_secs(10))).unwrap() {
        let resp: OperationResponse = decode(&body).unwrap();
        items.push(resp.operation);
    }
    let last = items.last().expect("at least the done transition");
    assert!(last.done, "final item must be the completed operation");
    assert_eq!(last.trials.len(), 1);
    assert!(
        items.iter().rev().skip(1).all(|o| !o.done),
        "done must be the final transition, in order"
    );

    // Zero polling: completion was pushed, not fetched.
    assert_eq!(service.metrics.histogram("GetOperation").count(), 0);
    assert_eq!(service.metrics.histogram("WaitOperation").count(), 1);
    wait_until("the watcher to drain", Duration::from_secs(10), || {
        service.metrics.watch_streams() == 0
    });
    server.shutdown();
}

/// CANCEL (dropping a stream handle) and an abrupt mid-stream TCP
/// disconnect both disarm the server-side watcher: the `watch_streams`
/// and `parked_responses` gauges return to zero, observed through
/// `GetServiceMetrics` like an external operator would.
#[test]
fn cancel_and_disconnect_leave_no_leaked_waiters() {
    let _serial = serial();
    if env_forced_v1() {
        eprintln!("skipping: OSSVIZIER_WIRE=v1 pins the legacy protocol");
        return;
    }
    let ds: Arc<dyn Datastore> = Arc::new(InMemoryDatastore::new());
    let config = test_config(Algorithm::RandomSearch);
    let study = ds
        .create_study(StudyProto {
            display_name: "leak".into(),
            spec: converters::study_config_to_proto(&config),
            ..Default::default()
        })
        .unwrap();
    // Never completed: any watcher on it lives until disarmed.
    let op = ds
        .create_operation(OperationProto {
            kind: OperationKind::SuggestTrials,
            study_name: study.name.clone(),
            client_id: "w0".into(),
            count: 1,
            ..Default::default()
        })
        .unwrap();

    let service = build_service(Arc::clone(&ds), |_| {}, 2);
    let server = start_server(Arc::clone(&service), 2);
    let addr = server.local_addr().to_string();

    // The observer uses its own connection and only reads metrics.
    let mut observer =
        VizierClient::for_study(Box::new(TcpTransport::connect(&addr).unwrap()), "none", "m");
    let watchers = |c: &mut VizierClient| {
        let m = c.service_metrics().unwrap();
        (gauge(&m, "watch_streams"), gauge(&m, "frontend.parked_responses"))
    };
    assert_eq!(watchers(&mut observer), (0, 0));

    let req = WaitOperationRequest { name: op.name.clone(), timeout_ms: 0 };

    // --- Explicit CANCEL: drop the stream handle, keep the connection.
    let mut t = TcpTransport::connect(&addr).unwrap();
    assert_eq!(t.wire_version(), 2);
    {
        let mut stream = t
            .call_stream(Method::WaitOperation, &encode(&req))
            .unwrap()
            .expect("watch stream");
        // Consume the registration snapshot so the watcher is armed.
        stream.next(Some(Duration::from_secs(10))).unwrap().expect("snapshot");
        wait_until("the watcher to arm", Duration::from_secs(10), || {
            service.metrics.watch_streams() == 1
        });
    } // drop sends CANCEL
    wait_until("CANCEL to disarm the watcher", Duration::from_secs(10), || {
        service.metrics.watch_streams() == 0
    });
    // The same connection is still healthy for ordinary RPCs.
    let m = {
        let mut c = VizierClient::for_study(Box::new(t), "none", "m2");
        c.service_metrics().unwrap()
    };
    assert_eq!(gauge(&m, "watch_streams"), 0);

    // --- Mid-stream disconnect: a hand-rolled v2 connection that dies
    // abruptly — no CANCEL frame, just a closed socket. The server-side
    // teardown hook must disarm the watcher all the same.
    {
        let mut raw = TcpStream::connect(&addr).unwrap();
        raw.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        write_v2(
            &mut raw,
            FrameKind::Hello,
            0,
            &encode(&HelloProto { version: WIRE_VERSION_MAX, max_inflight: 0 }),
        )
        .unwrap();
        let (head, payload) = read_frame(&mut raw).unwrap();
        let hello = parse_v2(head, payload).unwrap();
        assert_eq!(hello.kind, FrameKind::Hello);
        raw.write_all(&encode_v2_request(7, Method::WaitOperation, &req).unwrap()).unwrap();
        // Wait for the registration snapshot so the watcher is armed,
        // then drop the socket mid-stream.
        let (head, payload) = read_frame(&mut raw).unwrap();
        assert_eq!(parse_v2(head, payload).unwrap().kind, FrameKind::StreamItem);
        wait_until("the second watcher to arm", Duration::from_secs(10), || {
            service.metrics.watch_streams() == 1
        });
    } // TCP close, mid-stream
    wait_until("disconnect to disarm the watcher", Duration::from_secs(10), || {
        let (ws, parked) = watchers(&mut observer);
        ws == 0 && parked == 0
    });
    let m = observer.service_metrics().unwrap();
    assert_eq!(gauge(&m, "in_flight_policy_jobs"), 0);
    assert_eq!(hist_count(&m, "method.GetOperation"), 0, "no polling anywhere in this test");
    server.shutdown();
}

/// Acceptance: PythiaServer handler threads never block on policy
/// compute. While a policy run is parked on the gate (occupying a
/// compute thread), the `pythia-fe` pool stays at its thread budget and
/// still answers unrelated requests immediately — the same procfs
/// assertion shape as tests/async_dispatch.rs uses for the API server.
#[test]
fn pythia_handler_threads_never_block_on_policy_compute() {
    let _serial = serial();
    let ds: Arc<dyn Datastore> = Arc::new(InMemoryDatastore::new());
    let gate = Arc::new(Gate::default());
    let invocations = Arc::new(AtomicUsize::new(0));
    let mut registry = default_registry();
    {
        let (g, inv) = (Arc::clone(&gate), Arc::clone(&invocations));
        registry.register(
            "GATED",
            Arc::new(move |_| {
                Box::new(GatedPolicy { gate: Arc::clone(&g), invocations: Arc::clone(&inv) })
            }),
        );
    }

    // Figure-2 topology, two-phase bind (as in tests/service_loop.rs).
    let api_placeholder = VizierServer::start(
        VizierService::new(Arc::clone(&ds), Arc::new(RemotePythia::new("127.0.0.1:1")), 4),
        "127.0.0.1:0",
    )
    .unwrap();
    let api_addr = api_placeholder.local_addr().to_string();
    let fe_workers = 2;
    let pythia = PythiaServer::start_with(registry, &api_addr, "127.0.0.1:0", fe_workers).unwrap();
    let pythia_addr = pythia.local_addr().to_string();
    api_placeholder.shutdown();
    let service =
        VizierService::new(Arc::clone(&ds), Arc::new(RemotePythia::new(&pythia_addr)), 4);
    let api = VizierServer::start(Arc::clone(&service), &api_addr).unwrap();

    let config = test_config(Algorithm::Custom("GATED".into()));
    let study = service
        .create_study(CreateStudyRequest {
            study: StudyProto {
                display_name: "pythia-budget".into(),
                spec: converters::study_config_to_proto(&config),
                ..Default::default()
            },
        })
        .unwrap()
        .study;

    let suggester = {
        let api_addr = api_addr.clone();
        let study = study.name.clone();
        std::thread::spawn(move || {
            let t = TcpTransport::connect(&api_addr).unwrap();
            let mut client = VizierClient::for_study(Box::new(t), &study, "w0");
            client.get_suggestions(1).unwrap().len()
        })
    };
    wait_until("the policy run to park on the gate", Duration::from_secs(10), || {
        invocations.load(Ordering::SeqCst) >= 1
    });

    // The policy is parked on a compute thread ("vizier-worker-*"), NOT
    // on a pythia-fe handler: the pool is at budget and a fresh request
    // on a fresh connection gets an immediate answer.
    if let Some(threads) = threads_with_prefix("pythia-fe") {
        assert!(
            threads <= fe_workers + 2,
            "pythia front-end grew past its budget: {threads} threads \
             (budget {}; a handler is blocking on policy compute)",
            fe_workers + 2
        );
    }
    let start = Instant::now();
    let mut probe = TcpStream::connect(&pythia_addr).unwrap();
    probe.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    // Raw v1 frame with a bogus method byte: the prompt Unimplemented
    // error proves a handler worker was free while the policy computed.
    probe.write_all(&1u32.to_le_bytes()).unwrap();
    probe.write_all(&[200u8]).unwrap();
    probe.flush().unwrap();
    let mut r = BufReader::new(probe.try_clone().unwrap());
    match read_response::<_, EmptyResponse>(&mut r) {
        Err(FrameError::Rpc { status, .. }) => {
            assert_eq!(status, Status::Unimplemented);
        }
        other => panic!("expected Unimplemented from the free handler, got {other:?}"),
    }
    assert!(
        start.elapsed() < Duration::from_secs(5),
        "handler round-trip stalled behind the parked policy run"
    );

    gate.release();
    assert_eq!(suggester.join().unwrap(), 1);
    api.shutdown();
    pythia.shutdown();
}
