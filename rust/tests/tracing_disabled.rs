//! The disabled default is observably zero-cost state-wise: nothing in
//! this binary enables tracing, so a full client round trip must record
//! no spans, allocate no per-thread span rings, and `GetTraces` must
//! answer with nothing. This lives in its own test binary because the
//! tracing config latches process-wide on first use — `tests/tracing.rs`
//! latches it ON for its process, this one never does.

use ossvizier::client::transport::{call, TcpTransport};
use ossvizier::client::VizierClient;
use ossvizier::pyvizier::{Algorithm, MetricInformation, StudyConfig};
use ossvizier::service::{in_memory_service, ServerOptions, VizierServer};
use ossvizier::testing::poller_from_env;
use ossvizier::util::trace;
use ossvizier::wire::framing::Method;
use ossvizier::wire::messages::{GetTracesRequest, GetTracesResponse, ScaleType};

#[test]
fn disabled_tracing_records_nothing_and_get_traces_is_empty() {
    if std::env::var_os("OSSVIZIER_TRACE").is_some() {
        eprintln!("skipping: OSSVIZIER_TRACE is set, this binary asserts the disabled default");
        return;
    }

    let server = VizierServer::start_with(
        in_memory_service(2),
        "127.0.0.1:0",
        ServerOptions { workers: 2, poller: poller_from_env(), ..Default::default() },
    )
    .unwrap();
    let addr = server.local_addr().to_string();

    let mut config = StudyConfig::new("untraced");
    config.search_space.add_float("x", 0.0, 1.0, ScaleType::Linear);
    config.add_metric(MetricInformation::maximize("score"));
    config.algorithm = Algorithm::RandomSearch;
    let t = TcpTransport::connect(&addr).unwrap();
    let mut client =
        VizierClient::load_or_create_study(Box::new(t), "untraced", &config, "w0").unwrap();
    let trials = client.get_suggestions(2).unwrap();
    assert_eq!(trials.len(), 2);

    assert!(!trace::enabled(), "nothing in this binary may enable tracing");
    assert!(
        trace::snapshot().is_empty(),
        "no span may be recorded while tracing is disabled"
    );
    assert_eq!(
        trace::registered_rings(),
        0,
        "no thread may have allocated a span ring while disabled"
    );

    // The RPC surface agrees: GetTraces answers cleanly, with nothing.
    let mut t2 = TcpTransport::connect(&addr).unwrap();
    let resp: GetTracesResponse = call(
        &mut t2,
        Method::GetTraces,
        &GetTracesRequest { limit: 0, include_infra: true },
    )
    .unwrap();
    assert!(resp.traces.is_empty(), "GetTraces must be empty while disabled");
    let report = client.traces(0, true).unwrap();
    assert!(
        report.contains("no traces recorded"),
        "the rendered report must say so: {report:?}"
    );
    server.shutdown();
}
