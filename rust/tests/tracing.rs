//! End-to-end tracing coverage: a trace context propagates across a real
//! TCP wire-v2 round trip (the client span becomes the parent of the
//! server's rpc span), v1 connections stay trailer-free and get fresh
//! server-side roots, one coalesced policy run fans its policy-compute
//! span into every waiting operation's trace, and the acceptance path —
//! a WAL-backed suggest over TCP — yields a span tree with
//! frontend-queue, policy-compute, and wal-commit spans parented under
//! the rpc span, visible through `GetTraces` / `VizierClient::traces()`.
//!
//! The tracing config latches process-wide on first use, so every test
//! here starts with `init_tracing()` (sample rate 1.0, no slow log) and
//! the binary serializes through `serial()` — the span rings are global
//! and overlapping servers would interleave their spans. The disabled
//! default is covered by `tests/tracing_disabled.rs`, a separate binary
//! that never enables tracing.

use ossvizier::client::transport::{call, TcpTransport};
use ossvizier::client::VizierClient;
use ossvizier::datastore::memory::InMemoryDatastore;
use ossvizier::datastore::wal::WalDatastore;
use ossvizier::datastore::Datastore;
use ossvizier::pythia::policy::{Policy, PolicyError, SuggestDecision, SuggestRequest};
use ossvizier::pythia::supporter::PolicySupporter;
use ossvizier::pyvizier::{converters, Algorithm, MetricInformation, StudyConfig, TrialSuggestion};
use ossvizier::service::{build_service, ServerOptions, VizierServer, VizierService};
use ossvizier::testing::poller_from_env;
use ossvizier::util::trace::{self, SpanRecord};
use ossvizier::wire::framing::Method;
use ossvizier::wire::messages::{
    CreateStudyRequest, EmptyResponse, GetOperationRequest, OperationResponse, ScaleType,
    StudyProto, SuggestTrialsRequest,
};
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock};
use std::time::{Duration, Instant};

/// The span rings are process-global, so tests must not overlap.
fn serial() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Latch tracing on for this whole binary. First `init` wins; every test
/// passes the same values, so ordering between tests does not matter.
fn init_tracing() {
    trace::init(Some(1.0), None);
    assert!(trace::enabled(), "tracing must be on for this binary");
}

fn env_forced_v1() -> bool {
    std::env::var("OSSVIZIER_WIRE").map(|v| v == "v1").unwrap_or(false)
}

fn test_config(algorithm: Algorithm) -> StudyConfig {
    let mut c = StudyConfig::new("tracing");
    c.search_space.add_float("x", 0.0, 1.0, ScaleType::Linear);
    c.add_metric(MetricInformation::maximize("score"));
    c.algorithm = algorithm;
    c.seed = 29;
    c
}

fn wait_until(what: &str, deadline: Duration, mut cond: impl FnMut() -> bool) {
    let by = Instant::now() + deadline;
    while !cond() {
        assert!(Instant::now() < by, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

fn start_server(service: Arc<VizierService>, workers: usize) -> VizierServer {
    VizierServer::start_with(
        service,
        "127.0.0.1:0",
        ServerOptions { workers, poller: poller_from_env(), ..Default::default() },
    )
    .unwrap()
}

/// Span ids recorded so far — the diff baseline. Earlier tests in this
/// binary leave spans behind; everything below identifies its own spans
/// as "recorded after my baseline".
fn seen_ids() -> HashSet<u64> {
    trace::snapshot().iter().map(|r| r.span_id).collect()
}

/// Poll the global rings until `pred` holds (spans recorded on another
/// thread race the client's return) and hand back the snapshot.
fn wait_for_spans(
    what: &str,
    mut pred: impl FnMut(&[SpanRecord]) -> bool,
) -> Vec<SpanRecord> {
    let by = Instant::now() + Duration::from_secs(10);
    loop {
        let snap = trace::snapshot();
        if pred(&snap) {
            return snap;
        }
        assert!(Instant::now() < by, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

// ---------------------------------------------------------------------------
// Gated policy (same shape as tests/wire_matrix.rs): the first invocation
// blocks, so follow-on operations coalesce behind it deterministically.
// ---------------------------------------------------------------------------

#[derive(Default)]
struct Gate {
    open: Mutex<bool>,
    cv: Condvar,
}

impl Gate {
    fn release(&self) {
        *self.open.lock().unwrap() = true;
        self.cv.notify_all();
    }

    fn wait(&self) {
        let mut open = self.open.lock().unwrap();
        while !*open {
            open = self.cv.wait(open).unwrap();
        }
    }
}

struct GatedPolicy {
    gate: Arc<Gate>,
    invocations: Arc<AtomicUsize>,
}

impl Policy for GatedPolicy {
    fn suggest(
        &mut self,
        req: &SuggestRequest,
        _s: &dyn PolicySupporter,
    ) -> Result<SuggestDecision, PolicyError> {
        if self.invocations.fetch_add(1, Ordering::SeqCst) == 0 {
            self.gate.wait(); // only the first invocation blocks
        }
        Ok(SuggestDecision::from_flat(
            req,
            vec![TrialSuggestion::default(); req.total_count()],
        ))
    }
}

fn gated_service(
    ds: Arc<dyn Datastore>,
    policy_workers: usize,
) -> (Arc<VizierService>, Arc<Gate>, Arc<AtomicUsize>) {
    let gate = Arc::new(Gate::default());
    let invocations = Arc::new(AtomicUsize::new(0));
    let (g, inv) = (Arc::clone(&gate), Arc::clone(&invocations));
    let service = build_service(
        ds,
        move |reg| {
            reg.register(
                "GATED",
                Arc::new(move |_| {
                    Box::new(GatedPolicy {
                        gate: Arc::clone(&g),
                        invocations: Arc::clone(&inv),
                    })
                }),
            );
        },
        policy_workers,
    );
    (service, gate, invocations)
}

/// A wire-v2 round trip stitches one trace across the process boundary:
/// the client-side rpc span is the root, and the server's dispatch span
/// (carried over the trace-context trailer) parents directly to it.
#[test]
fn v2_round_trip_links_client_and_server_spans() {
    let _serial = serial();
    init_tracing();
    if env_forced_v1() {
        eprintln!("skipping: OSSVIZIER_WIRE=v1 pins the legacy protocol");
        return;
    }
    let server = start_server(ossvizier::service::in_memory_service(2), 2);
    let addr = server.local_addr().to_string();
    let mut t = TcpTransport::connect(&addr).unwrap();
    assert_eq!(t.wire_version(), 2, "HELLO negotiation must land on v2");

    let before = seen_ids();
    let _: EmptyResponse = call(&mut t, Method::Ping, &EmptyResponse::default()).unwrap();

    let client_code = trace::CLIENT_RPC_BASE + Method::Ping as u8 as u64;
    let server_code = trace::RPC_BASE + Method::Ping as u8 as u64;
    let fresh = |r: &SpanRecord, code: u64| r.name_code == code && !before.contains(&r.span_id);
    let snap = wait_for_spans("client and server ping spans", |s| {
        s.iter().any(|r| fresh(r, client_code)) && s.iter().any(|r| fresh(r, server_code))
    });
    let client_span = snap.iter().find(|r| fresh(r, client_code)).unwrap();
    let server_span = snap.iter().find(|r| fresh(r, server_code)).unwrap();
    assert_eq!(
        server_span.trace_id, client_span.trace_id,
        "both sides of the wire must land in one trace"
    );
    assert_eq!(
        server_span.parent_id, client_span.span_id,
        "the server span must parent to the client span from the trailer"
    );
    assert_eq!(client_span.parent_id, 0, "the client span is the trace root");
    server.shutdown();
}

/// A v1 connection never carries the trailer (the bytes are identical
/// with tracing on), so the server samples a fresh root and the client
/// side opens no span at all.
#[test]
fn v1_connection_stays_trailer_free_and_gets_a_fresh_root() {
    let _serial = serial();
    init_tracing();
    let server = start_server(ossvizier::service::in_memory_service(2), 2);
    let addr = server.local_addr().to_string();
    let mut t = TcpTransport::connect(&addr).unwrap();
    t.force_v1();
    assert_eq!(t.wire_version(), 1);

    let before = seen_ids();
    let _: EmptyResponse = call(&mut t, Method::Ping, &EmptyResponse::default()).unwrap();

    let server_code = trace::RPC_BASE + Method::Ping as u8 as u64;
    let snap = wait_for_spans("the v1 server ping span", |s| {
        s.iter().any(|r| r.name_code == server_code && !before.contains(&r.span_id))
    });
    let server_span = snap
        .iter()
        .find(|r| r.name_code == server_code && !before.contains(&r.span_id))
        .unwrap();
    assert_eq!(
        server_span.parent_id, 0,
        "no trailer on v1: the server span must be a fresh sampled root"
    );
    let client_code = trace::CLIENT_RPC_BASE + Method::Ping as u8 as u64;
    assert!(
        snap.iter().all(|r| before.contains(&r.span_id) || r.name_code != client_code),
        "the v1 client path must not open client-rpc spans"
    );
    server.shutdown();
}

/// One coalesced policy run serves K waiting operations; its single
/// policy-compute interval must be linked into each waiter's trace as a
/// distinct span record (same start/duration, that trace's rpc span as
/// parent).
#[test]
fn coalesced_policy_run_fans_into_every_waiting_trace() {
    let _serial = serial();
    init_tracing();
    let ds: Arc<dyn Datastore> = Arc::new(InMemoryDatastore::new());
    let (service, gate, invocations) = gated_service(Arc::clone(&ds), 1);
    let server = start_server(Arc::clone(&service), 2);
    let addr = server.local_addr().to_string();
    let config = test_config(Algorithm::Custom("GATED".into()));
    let study = service
        .create_study(CreateStudyRequest {
            study: StudyProto {
                display_name: "traced-coalesce".into(),
                spec: converters::study_config_to_proto(&config),
                ..Default::default()
            },
        })
        .unwrap()
        .study;

    let mut t = TcpTransport::connect(&addr).unwrap();
    let study_name = study.name.clone();
    let suggest = |t: &mut TcpTransport, cid: &str| -> String {
        let resp: OperationResponse = call(
            t,
            Method::SuggestTrials,
            &SuggestTrialsRequest {
                study_name: study_name.clone(),
                count: 1,
                client_id: cid.into(),
            },
        )
        .unwrap();
        resp.operation.name
    };

    // The first operation occupies the single policy worker (blocked on
    // the gate), so the next three queue behind it and coalesce into one
    // batch once it finishes.
    let _op1 = suggest(&mut t, "w1");
    wait_until("the gated policy run to start", Duration::from_secs(10), || {
        invocations.load(Ordering::SeqCst) >= 1
    });

    let before = seen_ids();
    let ops: Vec<String> = (2..=4).map(|i| suggest(&mut t, &format!("w{i}"))).collect();
    gate.release();
    for name in &ops {
        wait_until(&format!("{name} to complete"), Duration::from_secs(20), || {
            let resp: OperationResponse = call(
                &mut t,
                Method::GetOperation,
                &GetOperationRequest { name: name.clone() },
            )
            .unwrap();
            resp.operation.done
        });
    }
    assert_eq!(
        invocations.load(Ordering::SeqCst),
        2,
        "the three queued operations must coalesce into one policy run"
    );

    // The linked records are published before the operations complete,
    // so one snapshot after the waits is race-free.
    let snap = trace::snapshot();
    let fresh: Vec<&SpanRecord> = snap
        .iter()
        .filter(|r| r.name_code == trace::POLICY_COMPUTE && !before.contains(&r.span_id))
        .collect();
    // `fresh` holds op1's span (recorded after the gate opened) plus the
    // fan-in group; the group members are copies of one computation, so
    // they share the exact (start, duration) interval.
    let mut groups: HashMap<(u64, u64), Vec<&SpanRecord>> = HashMap::new();
    for r in fresh {
        groups.entry((r.start_us, r.dur_us)).or_default().push(r);
    }
    let batch = groups
        .values()
        .find(|g| g.len() == 3)
        .expect("one policy interval must be linked into exactly three traces");
    let trace_ids: HashSet<u64> = batch.iter().map(|r| r.trace_id).collect();
    assert_eq!(trace_ids.len(), 3, "the shared span lands in three distinct traces");
    let parents: HashSet<u64> = batch.iter().map(|r| r.parent_id).collect();
    assert_eq!(parents.len(), 3, "each copy parents to its own trace's rpc span");
    assert!(batch.iter().all(|r| r.parent_id != 0));
    server.shutdown();
}

/// Acceptance: a traced `SuggestTrials` against a WAL-backed server over
/// TCP yields a span tree with frontend-queue, policy-compute, and
/// wal-commit spans correctly parented under the rpc span — both in the
/// raw records and through the `GetTraces` RPC as an operator would see
/// it (`VizierClient::traces()`).
#[test]
fn acceptance_wal_backed_suggest_trace_over_tcp() {
    let _serial = serial();
    init_tracing();
    let dir = std::env::temp_dir().join(format!(
        "ossvizier-tracing-{}-{}",
        std::process::id(),
        ossvizier::util::id::next_uid()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    let ds = Arc::new(WalDatastore::open(dir.join("store.wal")).unwrap());
    let service = build_service(ds as Arc<dyn Datastore>, |_| {}, 2);
    let server = start_server(service, 2);
    let addr = server.local_addr().to_string();

    let t = TcpTransport::connect(&addr).unwrap();
    let config = test_config(Algorithm::RandomSearch);
    let mut client =
        VizierClient::load_or_create_study(Box::new(t), "traced-wal", &config, "w0").unwrap();
    let before = seen_ids();
    let trials = client.get_suggestions(1).unwrap();
    assert_eq!(trials.len(), 1);

    let rpc_code = trace::RPC_BASE + Method::SuggestTrials as u8 as u64;
    let snap = wait_for_spans("the traced suggest span tree", |s| {
        let Some(rpc) = s
            .iter()
            .find(|r| r.name_code == rpc_code && !before.contains(&r.span_id))
        else {
            return false;
        };
        let has = |code: u64| {
            s.iter().any(|r| {
                r.trace_id == rpc.trace_id && r.name_code == code && r.parent_id == rpc.span_id
            })
        };
        has(trace::FRONTEND_QUEUE) && has(trace::POLICY_COMPUTE) && has(trace::WAL_COMMIT)
    });
    let rpc = snap
        .iter()
        .find(|r| r.name_code == rpc_code && !before.contains(&r.span_id))
        .unwrap();
    let child = |code: u64| {
        snap.iter().find(|r| {
            r.trace_id == rpc.trace_id && r.name_code == code && r.parent_id == rpc.span_id
        })
    };
    let queue = child(trace::FRONTEND_QUEUE).expect("frontend-queue span under the rpc span");
    assert!(queue.dur_us >= 1, "the queue-wait note is clamped to >= 1us");
    assert!(
        queue.start_us <= rpc.start_us,
        "the retroactive queue span starts before its rpc span"
    );
    child(trace::POLICY_COMPUTE).expect("policy-compute span linked under the rpc span");
    child(trace::WAL_COMMIT).expect("wal-commit span under the rpc span");

    // The operator view of the same tree, fetched over the same wire.
    let report = client.traces(50, false).unwrap();
    for needle in ["rpc:SuggestTrials", "frontend-queue", "policy-compute", "wal-commit"] {
        assert!(
            report.contains(needle),
            "traces() report is missing {needle:?}:\n{report}"
        );
    }
    server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
