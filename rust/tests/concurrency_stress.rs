//! Concurrency stress tests for the sharded datastore + group-commit WAL
//! behind one live `VizierServer` (paper §3.1: the service must keep
//! serving "multiple parallel evaluations" without losing state).
//!
//! `OSSVIZIER_SOAK=1` (the nightly soak job) elevates the worker-thread
//! and round counts 4x to shake out races PR-sized runs are too short to
//! hit.

use ossvizier::client::{TcpTransport, VizierClient};
use ossvizier::datastore::memory::InMemoryDatastore;
use ossvizier::datastore::wal::WalDatastore;
use ossvizier::datastore::Datastore;
use ossvizier::pyvizier::{Algorithm, Measurement, MetricInformation, StudyConfig};
use ossvizier::service::{build_service, VizierServer};
use ossvizier::wire::messages::ScaleType;
use std::collections::HashSet;
use std::sync::Arc;

fn soak() -> bool {
    std::env::var_os("OSSVIZIER_SOAK").is_some()
}

/// Hammer width: 8 client workers normally, 32 under soak.
fn threads() -> usize {
    if soak() {
        32
    } else {
        8
    }
}

/// Per-worker round count, scaled 4x under soak.
fn rounds(base: usize) -> usize {
    if soak() {
        base * 4
    } else {
        base
    }
}

fn config(name: &str) -> StudyConfig {
    let mut c = StudyConfig::new(name);
    c.search_space.add_float("x", 0.0, 1.0, ScaleType::Linear);
    c.add_metric(MetricInformation::maximize("score"));
    c.algorithm = Algorithm::RandomSearch;
    c.seed = 17;
    c
}

fn tmp(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!(
        "ossvizier-stress-{name}-{}-{}",
        std::process::id(),
        ossvizier::util::id::next_uid()
    ));
    std::fs::create_dir_all(&d).unwrap();
    d.join("store.wal")
}

/// Spawn `threads()` workers against `addr`, each doing `rounds` of
/// suggest -> complete on the shared study. Returns the completed trial
/// ids per worker.
fn hammer(addr: &str, study: &str, rounds: usize) -> Vec<Vec<u64>> {
    let handles: Vec<_> = (0..threads())
        .map(|w| {
            let addr = addr.to_string();
            let study = study.to_string();
            std::thread::spawn(move || {
                let mut client = VizierClient::load_or_create_study(
                    Box::new(TcpTransport::connect(&addr).unwrap()),
                    &study,
                    &config(&study),
                    &format!("w{w}"),
                )
                .unwrap();
                let mut completed = Vec::with_capacity(rounds);
                for i in 0..rounds {
                    let t = client.get_suggestions(1).unwrap().remove(0);
                    client
                        .complete_trial(
                            t.id,
                            Some(&Measurement::new(1).with_metric("score", i as f64)),
                        )
                        .unwrap();
                    completed.push(t.id);
                }
                completed
            })
        })
        .collect();
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

#[test]
fn shared_study_hammering_loses_no_trials() {
    let ds = Arc::new(InMemoryDatastore::new());
    let service = build_service(Arc::clone(&ds) as Arc<dyn Datastore>, |_| {}, threads());
    let server = VizierServer::start(service, "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();

    let rounds = rounds(15);
    let per_worker = hammer(&addr, "stress-shared", rounds);

    // No two workers ever completed the same trial (trials are assigned
    // per client_id), and none were lost.
    let mut all: Vec<u64> = per_worker.iter().flatten().copied().collect();
    let unique: HashSet<u64> = all.iter().copied().collect();
    assert_eq!(unique.len(), all.len(), "workers completed disjoint trial sets");
    assert_eq!(all.len(), threads() * rounds);

    // Trial ids are dense and monotonic: every id in 1..=N was assigned
    // exactly once, none skipped, none duplicated.
    all.sort_unstable();
    assert_eq!(all, (1..=(threads() * rounds) as u64).collect::<Vec<u64>>());

    let study = ds.lookup_study("stress-shared").unwrap();
    assert_eq!(ds.trial_count(&study.name).unwrap(), threads() * rounds);
    server.shutdown();
}

#[test]
fn per_thread_studies_stay_consistent_across_shards() {
    let ds = Arc::new(InMemoryDatastore::new());
    let service = build_service(Arc::clone(&ds) as Arc<dyn Datastore>, |_| {}, threads());
    let server = VizierServer::start(service, "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();

    let rounds = rounds(12);
    let handles: Vec<_> = (0..threads())
        .map(|w| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let name = format!("stress-shard-{w}");
                let mut client = VizierClient::load_or_create_study(
                    Box::new(TcpTransport::connect(&addr).unwrap()),
                    &name,
                    &config(&name),
                    "solo",
                )
                .unwrap();
                for i in 0..rounds {
                    let t = client.get_suggestions(1).unwrap().remove(0);
                    client
                        .complete_trial(
                            t.id,
                            Some(&Measurement::new(1).with_metric("score", i as f64)),
                        )
                        .unwrap();
                }
                name
            })
        })
        .collect();
    let names: Vec<String> = handles.into_iter().map(|h| h.join().unwrap()).collect();

    // Per-study ids are dense 1..=rounds regardless of which shard the
    // study landed in.
    for display in &names {
        let study = ds.lookup_study(display).unwrap();
        let ids: Vec<u64> = ds
            .list_trials(&study.name)
            .unwrap()
            .into_iter()
            .map(|t| t.id)
            .collect();
        assert_eq!(ids, (1..=rounds as u64).collect::<Vec<u64>>(), "{display}");
    }

    // The shard decomposition covers exactly the studies that exist: the
    // union of per-shard contents equals list_studies, with no overlap.
    let mut union: Vec<String> = (0..ds.shard_count())
        .flat_map(|i| ds.studies_in_shard(i))
        .collect();
    let unique: HashSet<String> = union.iter().cloned().collect();
    assert_eq!(unique.len(), union.len(), "a study must live in exactly one shard");
    union.sort();
    let mut listed: Vec<String> = ds
        .list_studies()
        .unwrap()
        .into_iter()
        .map(|s| s.name)
        .collect();
    listed.sort();
    assert_eq!(union, listed);
    server.shutdown();
}

#[test]
fn wal_group_commit_survives_hammering_and_reopens_exact() {
    let path = tmp("hammer");
    let total;
    {
        let ds = Arc::new(WalDatastore::open(&path).unwrap());
        let service = build_service(Arc::clone(&ds) as Arc<dyn Datastore>, |_| {}, threads());
        let server = VizierServer::start(service, "127.0.0.1:0").unwrap();
        let addr = server.local_addr().to_string();

        let rounds = rounds(10);
        let per_worker = hammer(&addr, "stress-wal", rounds);
        total = per_worker.iter().map(Vec::len).sum::<usize>();
        assert_eq!(total, threads() * rounds);
        server.shutdown();
    } // drop = crash; the log is the only survivor

    let ds = WalDatastore::open(&path).unwrap();
    let study = ds.lookup_study("stress-wal").unwrap();
    assert_eq!(ds.trial_count(&study.name).unwrap(), total, "no acknowledged trial lost");
    let trials = ds.list_trials(&study.name).unwrap();
    let ids: Vec<u64> = trials.iter().map(|t| t.id).collect();
    assert_eq!(ids, (1..=total as u64).collect::<Vec<u64>>());
    // Every recovered trial is in its completed state (the ack covered
    // the mutate_trial record too, not just the create).
    assert!(trials.iter().all(|t| t.final_measurement.is_some()));
}
