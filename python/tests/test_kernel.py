"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes and dtypes; assert_allclose against ref is THE
core correctness signal for the compiled artifacts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, st

from compile.kernels import ref
from compile.kernels.acquisition import ucb_pallas
from compile.kernels.kernel_matrix import kernel_matrix_pallas


def rand(rng, *shape, dtype=np.float32):
    return rng.uniform(-2.0, 2.0, size=shape).astype(dtype)


class TestKernelMatrix:
    @given(
        n=st.integers(1, 70),
        m=st.integers(1, 70),
        d=st.integers(1, 9),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_across_shapes(self, n, m, d, seed):
        rng = np.random.default_rng(seed)
        x = rand(rng, n, d)
        y = rand(rng, m, d)
        got = kernel_matrix_pallas(x, y, 0.3, 1.5)
        want = ref.kernel_matrix(jnp.asarray(x), jnp.asarray(y), 0.3, 1.5)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)

    @given(
        tile=st.sampled_from([8, 32, 128]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_tile_size_does_not_change_result(self, tile, seed):
        rng = np.random.default_rng(seed)
        x = rand(rng, 50, 4)
        y = rand(rng, 37, 4)
        got = kernel_matrix_pallas(x, y, 0.25, 1.0, tile_n=tile, tile_m=tile)
        want = ref.kernel_matrix(jnp.asarray(x), jnp.asarray(y), 0.25, 1.0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)

    def test_diagonal_is_sigma2(self):
        rng = np.random.default_rng(0)
        x = rand(rng, 16, 3)
        k = np.asarray(kernel_matrix_pallas(x, x, 0.25, 2.0))
        # f32 sqdist expansion (x²+y²-2xy) leaves ~1e-5 relative error on
        # the diagonal even after the max(·, 0) clamp.
        np.testing.assert_allclose(np.diag(k), 2.0, rtol=1e-4)
        np.testing.assert_allclose(k, k.T, rtol=1e-5, atol=1e-6)

    def test_values_decay_with_distance(self):
        x = np.zeros((1, 2), np.float32)
        y = np.array([[0.1, 0.0], [1.0, 0.0], [3.0, 0.0]], np.float32)
        k = np.asarray(kernel_matrix_pallas(x, y, 1.0, 1.0))[0]
        assert k[0] > k[1] > k[2] > 0.0

    def test_bfloat16_dtype(self):
        # TPU-native dtype must run through the same kernel.
        rng = np.random.default_rng(1)
        x = jnp.asarray(rand(rng, 24, 4), dtype=jnp.bfloat16)
        y = jnp.asarray(rand(rng, 24, 4), dtype=jnp.bfloat16)
        got = kernel_matrix_pallas(x, y, 0.25, 1.0)
        assert got.dtype == jnp.bfloat16
        want = ref.kernel_matrix(x.astype(jnp.float32), y.astype(jnp.float32), 0.25, 1.0)
        np.testing.assert_allclose(
            np.asarray(got, dtype=np.float32), np.asarray(want), rtol=0.05, atol=0.05
        )


class TestUcb:
    @given(
        m=st.integers(1, 600),
        beta=st.floats(0.0, 5.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, m, beta, seed):
        rng = np.random.default_rng(seed)
        mean = rand(rng, m)
        var = np.abs(rand(rng, m))
        got = ucb_pallas(mean, var, np.float32(beta))
        want = ref.ucb(jnp.asarray(mean), jnp.asarray(var), beta)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)

    def test_negative_variance_clamped(self):
        mean = np.zeros(4, np.float32)
        var = np.array([-1.0, -0.1, 0.0, 1.0], np.float32)
        got = np.asarray(ucb_pallas(mean, var, np.float32(2.0)))
        np.testing.assert_allclose(got, [0.0, 0.0, 0.0, 2.0], atol=1e-6)

    def test_beta_zero_is_mean(self):
        rng = np.random.default_rng(2)
        mean = rand(rng, 33)
        var = np.abs(rand(rng, 33))
        got = np.asarray(ucb_pallas(mean, var, np.float32(0.0)))
        np.testing.assert_allclose(got, mean, rtol=1e-6)


class TestRefInternals:
    def test_sqdist_expansion_vs_direct(self):
        rng = np.random.default_rng(3)
        x = jnp.asarray(rand(rng, 20, 5))
        y = jnp.asarray(rand(rng, 15, 5))
        got = ref.pairwise_sqdist(x, y, 0.5)
        direct = jnp.sum(((x[:, None, :] - y[None, :, :]) / 0.5) ** 2, axis=-1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(direct), rtol=1e-4, atol=1e-4)

    def test_matern_limits(self):
        assert float(ref.matern52(jnp.asarray(0.0), 1.0)) == pytest.approx(1.0)
        assert float(ref.matern52(jnp.asarray(1e6), 1.0)) == pytest.approx(0.0, abs=1e-12)


def test_pallas_lowering_contains_mxu_contraction():
    """Structural check: the tiled kernel lowers to a dot-general (MXU) and
    does NOT materialize the (n, m, d) broadcast tensor."""
    x = jax.ShapeDtypeStruct((128, 8), jnp.float32)
    y = jax.ShapeDtypeStruct((128, 8), jnp.float32)
    hlo = jax.jit(lambda a, b: kernel_matrix_pallas(a, b)).lower(x, y).as_text()
    assert "dot" in hlo, "expected an MXU contraction in the lowering"
    assert "128,128,8" not in hlo, "broadcast distance tensor must not be materialized"
