"""L2 correctness: the full gp_suggest graph vs ref, and the masking
invariance the Rust runtime's padding relies on."""

import jax.numpy as jnp
import numpy as np
from _prop import given, st

from compile.kernels import ref
from compile.model import gp_suggest


def make_problem(rng, n_real, n_pad, d, m):
    x = np.zeros((n_pad, d), np.float32)
    x[:n_real] = rng.uniform(0.0, 1.0, size=(n_real, d))
    y = np.zeros(n_pad, np.float32)
    y[:n_real] = rng.normal(size=n_real)
    mask = np.zeros(n_pad, np.float32)
    mask[:n_real] = 1.0
    cand = rng.uniform(0.0, 1.0, size=(m, d)).astype(np.float32)
    return x, y, mask, cand


class TestGpSuggest:
    @given(
        n_real=st.integers(2, 20),
        d=st.integers(1, 6),
        m=st.integers(1, 40),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, n_real, d, m, seed):
        rng = np.random.default_rng(seed)
        x, y, mask, cand = make_problem(rng, n_real, 32, d, m)
        got = gp_suggest(x, y, mask, cand, np.float32(1e-4), np.float32(2.0))
        want = ref.gp_suggest_ref(
            jnp.asarray(x), jnp.asarray(y), jnp.asarray(mask), jnp.asarray(cand),
            1e-4, 2.0,
        )
        # f32 Cholesky + the `sigma2 - v.v` cancellation dominate the
        # error budget; 5e-3 absolute on acquisition scores is well below
        # anything that changes an argmax in practice.
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0.05, atol=5e-3)

    @given(seed=st.integers(0, 2**31 - 1))
    def test_padding_is_invariant(self, seed):
        """Scores must not depend on how much padding the runtime added."""
        rng = np.random.default_rng(seed)
        n_real, d, m = 10, 4, 16
        x, y, mask, cand = make_problem(rng, n_real, 16, d, m)
        small = gp_suggest(x, y, mask, cand, np.float32(1e-4), np.float32(2.0))
        # Same data padded to 64 rows.
        x2 = np.zeros((64, d), np.float32)
        x2[:n_real] = x[:n_real]
        y2 = np.zeros(64, np.float32)
        y2[:n_real] = y[:n_real]
        mask2 = np.zeros(64, np.float32)
        mask2[:n_real] = 1.0
        big = gp_suggest(x2, y2, mask2, cand, np.float32(1e-4), np.float32(2.0))
        np.testing.assert_allclose(np.asarray(small), np.asarray(big), rtol=1e-3, atol=1e-3)

    @given(seed=st.integers(0, 2**31 - 1))
    def test_dim_padding_with_zero_columns_is_invariant(self, seed):
        """The runtime pads d up to d_pad with zero columns; distances are
        unchanged, so scores must be too."""
        rng = np.random.default_rng(seed)
        x, y, mask, cand = make_problem(rng, 8, 16, 3, 12)
        base = gp_suggest(x, y, mask, cand, np.float32(1e-4), np.float32(2.0))
        xp = np.concatenate([x, np.zeros((16, 5), np.float32)], axis=1)
        cp = np.concatenate([cand, np.zeros((12, 5), np.float32)], axis=1)
        padded = gp_suggest(xp, y, mask, cp, np.float32(1e-4), np.float32(2.0))
        np.testing.assert_allclose(np.asarray(base), np.asarray(padded), rtol=1e-3, atol=1e-3)

    def test_ucb_prefers_known_good_region(self):
        """With beta=0 the score is the posterior mean: a candidate at the
        best observed point must outscore one at the worst."""
        rng = np.random.default_rng(7)
        n, d = 12, 2
        x, y, mask, _ = make_problem(rng, n, 32, d, 1)
        best = int(np.argmax(y[:n]))
        worst = int(np.argmin(y[:n]))
        cand = np.stack([x[best], x[worst]]).astype(np.float32)
        scores = np.asarray(gp_suggest(x, y, mask, cand, np.float32(1e-6), np.float32(0.0)))
        assert scores[0] > scores[1]

    def test_high_noise_reduces_confidence(self):
        """More observation noise -> larger posterior variance at a train
        point -> larger UCB-minus-mean gap (Appendix B.2 semantics)."""
        rng = np.random.default_rng(8)
        x, y, mask, _ = make_problem(rng, 10, 32, 3, 1)
        cand = x[:1].copy()
        def gap(noise):
            mean = np.asarray(gp_suggest(x, y, mask, cand, np.float32(noise), np.float32(0.0)))
            ucb = np.asarray(gp_suggest(x, y, mask, cand, np.float32(noise), np.float32(2.0)))
            return float(ucb[0] - mean[0])
        assert gap(1e-2) > gap(1e-6)
