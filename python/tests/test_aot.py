"""AOT path: lowering to HLO text must succeed and produce parseable,
parameter-complete modules (the contract rust/src/runtime relies on)."""

import json
import os
import subprocess
import sys

import pytest

from compile.aot import lower_variant, VARIANTS


def test_smallest_variant_lowers_to_hlo_text():
    text = lower_variant(8, 2, 16)
    assert text.startswith("HloModule"), text[:80]
    # Six parameters in the entry computation.
    assert "parameter(0)" in text
    assert "parameter(5)" in text
    # The Cholesky factorization survives lowering (dense linear algebra
    # not constant-folded away).
    assert "cholesky" in text.lower() or "triangular" in text.lower()


def test_entry_shapes_match_variant():
    text = lower_variant(8, 2, 16)
    assert "f32[8,2]" in text, "x_train shape"
    assert "f32[16,2]" in text, "candidates shape"
    assert "f32[16]" in text, "output shape"


def test_variant_table_is_sane():
    assert len(VARIANTS) >= 3
    for (n, d, m) in VARIANTS:
        assert n > 0 and d > 0 and m > 0
        # The Rust gp_bandit generates up to 256 candidates.
        assert m == 256


@pytest.mark.slow
def test_cli_writes_manifest(tmp_path):
    out = tmp_path / "artifacts"
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out),
         "--variants", "8:2:16"],
        check=True,
        cwd=os.path.dirname(os.path.dirname(__file__)),
    )
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["model"] == "gp_suggest"
    assert manifest["variants"] == [{"n": 8, "d": 2, "m": 16, "file": "gp_suggest_n8_d2_m16.hlo.txt"}]
    hlo = (out / "gp_suggest_n8_d2_m16.hlo.txt").read_text()
    assert hlo.startswith("HloModule")
