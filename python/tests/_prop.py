"""Property-testing shim: re-exports hypothesis `given`/`st` when the real
library is installed, otherwise provides a minimal deterministic stand-in
(25 seeded draws per property) so the suite runs in offline images."""

try:
    import hypothesis
    import hypothesis.strategies as st
    from hypothesis import given

    hypothesis.settings.register_profile(
        "ci", deadline=None, max_examples=25, derandomize=True
    )
    hypothesis.settings.load_profile("ci")
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import functools
    import inspect
    import random

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, sampler):
            self.sampler = sampler

    class _Strategies:
        @staticmethod
        def integers(lo, hi):
            return _Strategy(lambda r: r.randint(lo, hi))

        @staticmethod
        def floats(lo, hi):
            return _Strategy(lambda r: r.uniform(lo, hi))

        @staticmethod
        def sampled_from(xs):
            return _Strategy(lambda r: r.choice(list(xs)))

    st = _Strategies()

    def given(**strats):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args):
                rng = random.Random(0xC0FFEE)
                for _ in range(25):
                    drawn = {k: s.sampler(rng) for k, s in strats.items()}
                    fn(*args, **drawn)

            # Hide the strategy-supplied parameters from pytest's fixture
            # resolution (inspect.signature follows __wrapped__ otherwise).
            sig = inspect.signature(fn)
            params = [p for name, p in sig.parameters.items() if name not in strats]
            wrapper.__signature__ = sig.replace(parameters=params)
            del wrapper.__wrapped__
            return wrapper

        return deco


__all__ = ["given", "st", "HAVE_HYPOTHESIS"]
