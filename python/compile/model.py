"""L2: the GP-bandit compute graph in JAX, calling the L1 Pallas kernels.

`gp_suggest` is the function the Rust coordinator executes through PJRT:
given padded training data and a candidate batch, it returns UCB
acquisition scores. Shapes are static (PJRT AOT requirement); variable
trial counts are handled with a row mask — see
python/compile/kernels/ref.py for the masking math and
rust/src/runtime/gp_artifact.rs for the padding done on the Rust side.
"""

import jax.numpy as jnp
import jax.scipy.linalg as jsl

from compile.kernels.acquisition import ucb_pallas
from compile.kernels.kernel_matrix import kernel_matrix_pallas

LENGTHSCALE = 0.25
SIGMA2 = 1.0


def gp_suggest(x_train, y_train, mask, candidates, noise, beta):
    """Masked GP posterior + UCB scores over a candidate batch.

    Args:
      x_train:    f32 (n_pad, d) unit-cube inputs, padded rows zero.
      y_train:    f32 (n_pad,) objectives (maximization orientation).
      mask:       f32 (n_pad,) 1.0 = real row, 0.0 = padding.
      candidates: f32 (m, d) points to score.
      noise:      f32 scalar observation-noise variance (the Appendix-B.2
                  hint, mapped by the coordinator: Low=1e-6, High=1e-2).
      beta:       f32 scalar UCB exploration coefficient.

    Returns:
      f32 (m,) acquisition scores (higher = more promising).
    """
    n = x_train.shape[0]
    cnt = jnp.maximum(jnp.sum(mask), 1.0)
    y_mean = jnp.sum(y_train * mask) / cnt
    y_var = jnp.sum(mask * (y_train - y_mean) ** 2) / cnt
    y_std = jnp.sqrt(jnp.maximum(y_var, 1e-12))
    y_norm = mask * (y_train - y_mean) / y_std

    # L1 kernel: tiled Matérn-5/2 Gram matrix.
    k = kernel_matrix_pallas(x_train, x_train, LENGTHSCALE, SIGMA2)
    mask2d = mask[:, None] * mask[None, :]
    eye = jnp.eye(n, dtype=x_train.dtype)
    k = mask2d * k + (1.0 - mask2d) * eye + noise * eye

    chol = jsl.cholesky(k, lower=True)
    alpha = jsl.cho_solve((chol, True), y_norm)

    # L1 kernel: cross Gram matrix, masked to real rows.
    kstar = kernel_matrix_pallas(x_train, candidates, LENGTHSCALE, SIGMA2) * mask[:, None]
    mean_n = kstar.T @ alpha
    v = jsl.solve_triangular(chol, kstar, lower=True)
    var_n = jnp.maximum(SIGMA2 - jnp.sum(v * v, axis=0), 1e-12)

    mean = y_mean + y_std * mean_n
    var = (y_std ** 2) * var_n
    # L1 kernel: fused UCB.
    return ucb_pallas(mean, var, beta)
