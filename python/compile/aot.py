"""AOT lowering: jax -> HLO text artifacts for the Rust PJRT runtime.

HLO *text* (not `.serialize()`d protos) is the interchange format: jax
>= 0.5 emits HloModuleProtos with 64-bit instruction ids which the xla
crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids and round-trips cleanly. See
/opt/xla-example/gen_hlo.py and DESIGN.md §3.

One artifact is emitted per (n_pad, d_pad, m) shape variant, plus a JSON
manifest the Rust artifact registry reads. Usage:

    cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile.model import gp_suggest

# (n_pad, d_pad, m): padded train rows, padded dims, candidate count.
# Matches MAX_TRAIN / CANDIDATES in rust/src/policies/gp_bandit.rs.
VARIANTS = [
    (32, 8, 256),
    (128, 8, 256),
    (256, 8, 256),
    (32, 16, 256),
    (128, 16, 256),
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(n: int, d: int, m: int) -> str:
    f32 = jnp.float32
    spec = lambda shape: jax.ShapeDtypeStruct(shape, f32)  # noqa: E731
    traced = jax.jit(gp_suggest).trace(
        spec((n, d)),      # x_train
        spec((n,)),        # y_train
        spec((n,)),        # mask
        spec((m, d)),      # candidates
        spec(()),          # noise
        spec(()),          # beta
    )
    # Lower for the TPU platform: cholesky/triangular_solve stay native HLO
    # ops (which the runtime's XLA expands itself) instead of the CPU
    # path's LAPACK typed-FFI custom-calls, which xla_extension 0.5.1
    # cannot compile. The Pallas kernels were already inlined to plain ops
    # at trace time by interpret=True, so no Mosaic custom-call appears.
    lowered = traced.lower(lowering_platforms=("tpu",))
    return to_hlo_text(lowered)


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument(
        "--variants",
        default=None,
        help="comma-separated n:d:m triples (default: built-in set)",
    )
    args = parser.parse_args()

    variants = VARIANTS
    if args.variants:
        variants = [tuple(int(x) for x in v.split(":")) for v in args.variants.split(",")]

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"model": "gp_suggest", "inputs": ["x_train", "y_train", "mask",
                                                  "candidates", "noise", "beta"],
                "variants": []}
    for (n, d, m) in variants:
        name = f"gp_suggest_n{n}_d{d}_m{m}.hlo.txt"
        path = os.path.join(args.out_dir, name)
        text = lower_variant(n, d, m)
        with open(path, "w") as f:
            f.write(text)
        manifest["variants"].append({"n": n, "d": d, "m": m, "file": name})
        print(f"wrote {path} ({len(text)} chars)")

    manifest_path = os.path.join(args.out_dir, "manifest.json")
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {manifest_path}")


if __name__ == "__main__":
    main()
