"""L1 Pallas kernel: fused UCB acquisition scoring.

Small but on the hot path: given posterior mean/variance for a candidate
batch, compute `mean + beta * sqrt(max(var, 0))` in one fused elementwise
pass (one VMEM round-trip instead of three separate HBM-bound ops).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 256


def _ucb_kernel(mean_ref, var_ref, beta_ref, out_ref):
    mean = mean_ref[...]
    var = jnp.maximum(var_ref[...], 0.0)
    beta = beta_ref[0]
    out_ref[...] = mean + beta * jnp.sqrt(var)


@jax.jit
def ucb_pallas(mean, var, beta):
    """UCB scores for a 1-D candidate batch.

    Shapes: mean (m,), var (m,), beta scalar -> (m,).
    """
    (m,) = mean.shape
    t = min(TILE, m)
    beta_arr = jnp.reshape(beta, (1,)).astype(mean.dtype)
    return pl.pallas_call(
        _ucb_kernel,
        grid=(pl.cdiv(m, t),),
        in_specs=[
            pl.BlockSpec((t,), lambda i: (i,)),
            pl.BlockSpec((t,), lambda i: (i,)),
            # Broadcast scalar: same (1,) block for every grid step.
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((t,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), mean.dtype),
        interpret=True,
    )(mean, var, beta_arr)
