"""Pure-jnp oracle for the GP-bandit numeric core (L1/L2 correctness).

Mirrors rust/src/policies/gp_math.rs. Everything here is the *reference*
implementation: the Pallas kernels (kernel_matrix.py, acquisition.py) and
the full model graph (model.py) are validated against these functions by
pytest + hypothesis, and the Rust fallback backend implements the same
formulas.
"""

import jax.numpy as jnp
import jax.scipy.linalg as jsl

SQRT5 = 5.0 ** 0.5


def matern52(r2, sigma2=1.0):
    """Matérn-5/2 kernel from *squared scaled distance*."""
    r = jnp.sqrt(jnp.maximum(r2, 0.0))
    return sigma2 * (1.0 + SQRT5 * r + (5.0 / 3.0) * r2) * jnp.exp(-SQRT5 * r)


def pairwise_sqdist(x, y, lengthscale):
    """Squared scaled distances: out[i, j] = ||(x_i - y_j) / ls||^2.

    Uses the |a|^2 + |b|^2 - 2ab expansion (the MXU-friendly form the
    Pallas kernel tiles on TPU), clamped at zero for numeric safety.
    """
    xs = x / lengthscale
    ys = y / lengthscale
    xn = jnp.sum(xs * xs, axis=1)[:, None]
    yn = jnp.sum(ys * ys, axis=1)[None, :]
    cross = xs @ ys.T
    return jnp.maximum(xn + yn - 2.0 * cross, 0.0)


def kernel_matrix(x, y, lengthscale, sigma2=1.0):
    """K[i, j] = matern52(||x_i - y_j|| / ls)."""
    return matern52(pairwise_sqdist(x, y, lengthscale), sigma2)


def ucb(mean, var, beta):
    """Upper-confidence-bound acquisition."""
    return mean + beta * jnp.sqrt(jnp.maximum(var, 0.0))


def gp_suggest_ref(x_train, y_train, mask, candidates, noise, beta,
                   lengthscale=0.25, sigma2=1.0):
    """Reference for the full L2 graph: masked GP posterior + UCB scores.

    Args:
      x_train:    (n_pad, d) unit-cube training inputs (padded rows zeros).
      y_train:    (n_pad,) objective values (maximization; padded zeros).
      mask:       (n_pad,) 1.0 for real rows, 0.0 for padding.
      candidates: (m, d) points to score.
      noise:      scalar observation-noise variance.
      beta:       scalar UCB coefficient.

    Returns:
      (m,) acquisition scores. Padded training rows must not affect the
      output (tested as an invariance property).
    """
    n = x_train.shape[0]
    cnt = jnp.maximum(jnp.sum(mask), 1.0)
    # Masked standardization of y.
    y_mean = jnp.sum(y_train * mask) / cnt
    y_var = jnp.sum(mask * (y_train - y_mean) ** 2) / cnt
    y_std = jnp.sqrt(jnp.maximum(y_var, 1e-12))
    y_norm = mask * (y_train - y_mean) / y_std

    # Masked kernel matrix: identity on padded rows/cols keeps Cholesky
    # well-posed without influencing real entries.
    k = kernel_matrix(x_train, x_train, lengthscale, sigma2)
    mask2d = mask[:, None] * mask[None, :]
    eye = jnp.eye(n)
    k = mask2d * k + (1.0 - mask2d) * eye + noise * eye

    chol = jsl.cholesky(k, lower=True)
    alpha = jsl.cho_solve((chol, True), y_norm)

    kstar = kernel_matrix(x_train, candidates, lengthscale, sigma2) * mask[:, None]
    mean_n = kstar.T @ alpha
    v = jsl.solve_triangular(chol, kstar, lower=True)
    var_n = jnp.maximum(sigma2 - jnp.sum(v * v, axis=0), 1e-12)

    mean = y_mean + y_std * mean_n
    var = (y_std ** 2) * var_n
    return ucb(mean, var, beta)
