"""L1 Pallas kernel: tiled Matérn-5/2 kernel-matrix computation.

This is the O(n·m·d) hot spot of the GP backend. The TPU-shaped design
(DESIGN.md §Hardware-Adaptation):

* grid over (i, j) output tiles of shape (TILE_N, TILE_M);
* each step loads one (TILE_N, d) block of X and one (TILE_M, d) block of
  Y into VMEM via BlockSpec;
* the -2·X·Yᵀ term of the squared-distance expansion is a (TILE_N, d) ×
  (d, TILE_M) contraction — `jnp.dot` inside the kernel targets the MXU;
* the Matérn transcendental tail (sqrt/exp) is fused elementwise on the
  VPU before the tile is written back, so the n×m×d distance tensor is
  never materialized in HBM.

VMEM per grid step at TILE=128, d=16, f32:
  2 · 128·16·4 B (inputs) + 128·128·4 B (output) ≈ 80 KiB  « 16 MiB VMEM,
leaving headroom for double-buffering (the default Pallas pipeline).

`interpret=True` is mandatory on this image: real TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute. Numerics are
identical; TPU performance is estimated analytically in DESIGN.md.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SQRT5 = 5.0 ** 0.5

# Default tile sizes (multiples of the 8x128 TPU vector lane layout).
TILE_N = 128
TILE_M = 128


def _kernel(x_ref, y_ref, out_ref, *, inv_ls, sigma2):
    """One (TILE_N, TILE_M) output tile."""
    x = x_ref[...] * inv_ls          # (tn, d)   VMEM
    y = y_ref[...] * inv_ls          # (tm, d)   VMEM
    xn = jnp.sum(x * x, axis=1)[:, None]
    yn = jnp.sum(y * y, axis=1)[None, :]
    # MXU contraction; f32 accumulation.
    cross = jnp.dot(x, y.T, preferred_element_type=jnp.float32)
    r2 = jnp.maximum(xn + yn - 2.0 * cross, 0.0)
    # Fused Matérn-5/2 tail on the VPU.
    r = jnp.sqrt(r2)
    out_ref[...] = (sigma2 * (1.0 + SQRT5 * r + (5.0 / 3.0) * r2)
                    * jnp.exp(-SQRT5 * r)).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("lengthscale", "sigma2", "tile_n", "tile_m"))
def kernel_matrix_pallas(x, y, lengthscale=0.25, sigma2=1.0,
                         tile_n=TILE_N, tile_m=TILE_M):
    """K = matern52(pairwise_dist(x, y) / lengthscale), Pallas-tiled.

    Shapes: x (n, d), y (m, d) -> (n, m). n and m need not be multiples of
    the tile size (Pallas masks the ragged edge blocks).
    """
    n, d = x.shape
    m, d2 = y.shape
    assert d == d2, f"dim mismatch {d} vs {d2}"
    tn = min(tile_n, n)
    tm = min(tile_m, m)
    grid = (pl.cdiv(n, tn), pl.cdiv(m, tm))
    return pl.pallas_call(
        functools.partial(_kernel, inv_ls=1.0 / lengthscale, sigma2=sigma2),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tn, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tm, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tn, tm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), x.dtype),
        interpret=True,  # CPU path; real-TPU lowering is compile-only here
    )(x, y)
